"""End-to-end driver: the ETL pipeline (distributed dataframe ops on the
runtime) feeds LM training, with checkpoints and resume — the paper's
'data engineering + deep learning under one execution framework'.

Presets:
  --preset ci    ~3M param model, 60 steps   (default; minutes on CPU)
  --preset full  ~100M param qwen3-style model, 300 steps
Resume after interruption:  just re-run with the same --ckpt dir.
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import build_communicator
from repro.launch.mesh import make_local_mesh
from repro.train.data import SyntheticCorpus, etl_token_batches, make_events
from repro.train.optimizer import OptimizerConfig
from repro.train.trainer import Trainer


def model_for(preset: str) -> tuple[ModelConfig, ShapeConfig, int]:
    if preset == "full":
        # ~100M-param qwen3-family config (assigned arch, scaled depth/width)
        cfg = dataclasses.replace(
            get_config("qwen3-8b"), name="qwen3-100m", n_layers=12,
            d_model=640, n_heads=10, n_kv_heads=2, head_dim=64, d_ff=1792,
            vocab_size=32768, dtype="float32", remat=False)
        return cfg, ShapeConfig("t", "train", 256, 8), 300
    cfg = dataclasses.replace(
        reduced(get_config("qwen3-8b")), n_layers=4, d_model=128, d_ff=256,
        vocab_size=2048)
    return cfg, ShapeConfig("t", "train", 128, 8), 60


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="ci", choices=["ci", "full"])
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--synthetic", action="store_true",
                    help="skip the ETL stage and use the synthetic corpus")
    args = ap.parse_args()

    cfg, shape, steps = model_for(args.preset)
    steps = args.steps or steps
    print(f"model {cfg.name}: {cfg.param_count() / 1e6:.1f}M params, "
          f"{steps} steps of batch {shape.global_batch} x seq {shape.seq_len}")

    # ---- stage 1: ETL on the runtime --------------------------------------
    if args.synthetic:
        corpus = SyntheticCorpus(cfg.vocab_size)
        batches = corpus.batches(shape.global_batch, shape.seq_len, steps)
    else:
        comm = build_communicator(jax.devices(), axes=("df",))
        need = steps * shape.global_batch * shape.seq_len
        events = make_events(max(next_pow2(need * 2), 1 << 15),
                             cfg.vocab_size, seed=0)
        doc_meta = {"doc_id": np.arange(256, dtype=np.int32),
                    "weight": np.ones(256, np.float32)}
        etl = list(etl_token_batches(
            comm, events, doc_meta, batch=shape.global_batch,
            seq=shape.seq_len,
            capacity_per_rank=len(events["event_id"]) // comm.size * 2 + 64))
        print(f"[etl] produced {len(etl)} batches via join+sort pipeline")
        # cycle ETL output if shorter than the run
        batches = (etl[i % len(etl)] for i in range(steps))

    # ---- stage 2: training with checkpoint/restart ------------------------
    mesh = make_local_mesh(1, 1)
    ocfg = OptimizerConfig(peak_lr=3e-3, warmup_steps=max(steps // 10, 5),
                           total_steps=steps)
    trainer = Trainer(cfg, mesh, ParallelConfig(), shape, ocfg,
                      ckpt_dir=args.ckpt, ckpt_every=max(steps // 3, 10))
    state = trainer.maybe_restore()
    if state:
        print(f"[resume] restored step {state.step} from {args.ckpt}")
    state, losses = trainer.fit(batches, steps=steps, state=state,
                                log_every=max(steps // 15, 1))
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}) "
          f"at step {state.step}")
    assert losses[-1] < losses[0], "loss did not decrease"


def next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


if __name__ == "__main__":
    main()
