"""Serving example, in two acts on the same pilot runtime:

1. the STATIC engine as one opaque task next to an ETL task (MPMD
   heterogeneous execution — the original demo);
2. the CONTINUOUS engine through ``ServeDriver``: prefill and decode as
   separately-tagged scheduler pipelines, serve telemetry in the session
   trace, and a ``ServeAutoscaler`` watching the queue/slot gauges.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced
from repro.core import (PilotDescription, PilotManager, RaptorMaster,
                        ResourceManager, SchedulerSession, TaskDescription,
                        ThreadExecutor)
from repro.dataframe import ops_dist as D
from repro.models import get_model
from repro.serve import (AutoscaleConfig, ContinuousEngine, Request,
                         ServeAutoscaler, ServeDriver, ServeEngine,
                         greedy_reference)


def main():
    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")), n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    requests = [
        Request(prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                max_new_tokens=8, uid=i)
        for i, L in enumerate([4, 6, 4, 6, 5, 4])
    ]

    def serve_task(comm):
        engine = ServeEngine(cfg, params, max_batch=4, max_seq=32)
        return engine.run_requests(requests)

    def etl_task(comm):
        data = {"k": rng.integers(0, 999, 2000).astype(np.int32)}
        t = D.shard_table(comm, data, 2000 // comm.size * 2 + 64)
        out, _ = D.make_dist_sort(comm.mesh, "k")(t)
        return int(D.collect_table(out)["k"][-1])

    pm = PilotManager()
    n = len(jax.devices())
    pilot = pm.submit_pilot(PilotDescription(n_devices=n))
    master = RaptorMaster(pilot)
    master.submit(TaskDescription(name="serve", ranks=max(n // 2, 1),
                                  fn=serve_task, tags={"pipeline": "serve"}))
    master.submit(TaskDescription(name="etl", ranks=max(n // 2, 1),
                                  fn=etl_task, tags={"pipeline": "etl"}))
    rep = master.run(timeout=600)
    serve_out = next(t.result for t in rep.tasks if t.desc.name == "serve")
    etl_out = next(t.result for t in rep.tasks if t.desc.name == "etl")
    print(f"[runtime] served {len(serve_out)} requests + ETL max key {etl_out} "
          f"in {rep.makespan:.2f}s")

    # verify one sequence against the full-forward oracle
    ref = greedy_reference(cfg, params, requests[0].prompt, 8)
    assert (serve_out[0] == ref).all()
    print("generated (req 0):", serve_out[0].tolist(), "== oracle ✓")

    # -- act 2: continuous batching as scheduler pipelines ----------------
    engine = ContinuousEngine(cfg, params, max_batch=2, max_seq=32)
    ex = ThreadExecutor(build_comm=False, tick=0.01)
    sess = SchedulerSession(ex, ResourceManager(["d0", "d1"]), tick=0.01)
    autoscaler = ServeAutoscaler(
        grow=lambda: ex.inject_grow([f"g{len(autoscaler.actions)}"]),
        retire=lambda: None,
        config=AutoscaleConfig(queue_high=2, sustain_s=0.01,
                               cooldown_s=0.05, max_workers=2))
    driver = ServeDriver(engine, sess, autoscaler=autoscaler)
    out = driver.run(requests, timeout=300)
    rep = sess.drain(timeout=60).close()
    for r in requests:
        ref = greedy_reference(cfg, params, r.prompt, r.max_new_tokens)
        assert (out[r.uid] == ref).all()
    pipes = sorted({e.pipeline for e in rep.trace if e.kind == "dispatch"})
    tel = [e for e in rep.trace if e.kind == "telemetry"]
    print(f"[continuous] {len(out)} requests through pipelines {pipes}, "
          f"{engine.metrics.get('serve_decode_steps')} decode rounds, "
          f"{len(tel)} telemetry events, "
          f"{len(autoscaler.actions)} autoscale actions == oracle ✓")


if __name__ == "__main__":
    main()
