"""Quickstart: the three pillars of the framework in ~60 seconds on CPU.

  1. a pilot + heterogeneous runtime executing dataframe tasks on private
     sub-mesh communicators (the paper's contribution),
  2. a distributed dataframe op validated against numpy,
  3. a few training steps of a (reduced) assigned architecture.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.core import (PilotDescription, PilotManager, RaptorMaster,
                        TaskDescription)
from repro.dataframe import ops_dist as D
from repro.launch.mesh import make_local_mesh
from repro.train.data import SyntheticCorpus
from repro.train.trainer import Trainer


def main():
    # ---- 1. pilot runtime -------------------------------------------------
    pm = PilotManager()                       # all local devices
    pilot = pm.submit_pilot(PilotDescription(n_devices=len(jax.devices())))
    master = RaptorMaster(pilot)

    def sort_task(comm):
        rng = np.random.default_rng(0)
        data = {"k": rng.integers(0, 10_000, 5_000).astype(np.int32)}
        table = D.shard_table(comm, data, 5_000 // comm.size * 2 + 64)
        out, overflow = D.make_dist_sort(comm.mesh, "k")(table)
        got = D.collect_table(out)["k"]
        assert (np.diff(got) >= 0).all() and len(got) == 5_000
        return float(got[-1])

    master.submit(TaskDescription(name="sort", ranks=len(jax.devices()),
                                  fn=sort_task, tags={"pipeline": "etl"}))
    report = master.run()
    print(f"[runtime] sort task done in {report.makespan:.2f}s, "
          f"comm build {report.overhead_total * 1e3:.2f}ms, "
          f"max key = {report.tasks[0].result}")

    # ---- 2. train a reduced assigned arch ---------------------------------
    cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), n_layers=2)
    mesh = make_local_mesh(1, 1)
    trainer = Trainer(cfg, mesh, ParallelConfig(),
                      ShapeConfig("t", "train", 64, 4))
    corpus = SyntheticCorpus(cfg.vocab_size)
    state, losses = trainer.fit(corpus.batches(4, 64, 12), steps=12,
                                log_every=4)
    print(f"[train] loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"over {len(losses)} steps")
    assert losses[-1] < losses[0]
    print("quickstart OK")


if __name__ == "__main__":
    main()
