"""The paper's headline experiment as a runnable example: heterogeneous
(shared-pool) vs batch (static-partition) execution of two MPMD pipelines —
a join DAG and a sort DAG — on one resource pool, with *continuous DAG
release*: each stage is submitted the moment its own deps complete, so a
freed device immediately backfills work from any pipeline (expect the
heterogeneous policy to win; paper: 4-15%).

Run with several host devices to see real interleaving:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/etl_pipeline.py
"""
import time

import jax
import numpy as np

from repro.core import (BATCH, HETEROGENEOUS, PilotDescription, PilotManager,
                        Pipeline, run_pipelines)
from repro.dataframe import ops_dist as D

ROWS = 20_000


def sort_payload(comm, *_deps):
    rng = np.random.default_rng(1)
    data = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32)}
    t = D.shard_table(comm, data, ROWS // comm.size * 2 + 64)
    out, _ = D.make_dist_sort(comm.mesh, "k")(t)
    jax.block_until_ready(out.columns["k"])
    time.sleep(1.0)    # simulated residual work: this container has ONE core,
                       # so cross-task parallelism is demonstrated via sleep
    return "sorted"


def join_payload(comm, *_deps):
    rng = np.random.default_rng(2)
    cap = ROWS // comm.size * 2 + 64
    a = D.shard_table(comm, {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
                             "v": rng.normal(size=ROWS).astype(np.float32)}, cap)
    b = D.shard_table(comm, {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
                             "w": rng.normal(size=ROWS).astype(np.float32)}, cap)
    out, _ = D.make_dist_join(comm.mesh, "k", out_factor=3.0)(a, b)
    jax.block_until_ready(out.columns["k"])
    time.sleep(3.0)    # joins are the long pole (see sort_payload note)
    return "joined"


def build_pipelines(n_dev):
    """Two DAG pipelines: 'join' is one heavy stage plus a cheap dependent
    summarize stage; 'sort' is a chain of sorts.  Under continuous release
    the summarize stage starts the moment its join finishes — while the
    other pipeline's sorts are still running (no wave barrier)."""
    per = max(n_dev // 2, 1)
    join = Pipeline("join")
    join.add("join0", ranks=per, fn=join_payload)
    join.add("join1", ranks=per, fn=join_payload)
    join.add("summarize", ranks=per,
             fn=lambda comm, *deps: f"summary({','.join(map(str, deps))})",
             deps=["join0", "join1"])
    sort = Pipeline("sort")
    sort.add("sort0", ranks=per, fn=sort_payload)
    sort.add("sort1", ranks=per, fn=sort_payload)
    sort.add("sort2", ranks=per, fn=sort_payload, deps=["sort0"])
    sort.add("sort3", ranks=per, fn=sort_payload, deps=["sort1"])
    return [join, sort]


def print_timeline(report, t0):
    for e in report.trace:
        if e.kind in ("dispatch", "done"):
            print(f"    t={e.t - t0:6.2f}s {e.kind:>8s} {e.task:<16s} "
                  f"ranks={e.ranks}")


def main():
    n = len(jax.devices())
    results = {}
    for policy in (HETEROGENEOUS, BATCH):
        pm = PilotManager()
        pilot = pm.submit_pilot(PilotDescription(n_devices=n))
        t0 = time.perf_counter()
        res, rep = run_pipelines(build_pipelines(n), pilot.resource_manager,
                                 policy=policy, timeout=900)
        assert res[("join", "summarize")].startswith("summary")
        results[policy] = rep.makespan
        print(f"[{policy:>13s}] makespan {rep.makespan:.2f}s  "
              f"(comm-build total {rep.overhead_total * 1e3:.1f}ms, "
              f"{len(rep.events('dispatch'))} dispatches)")
        print_timeline(rep, t0)
    impr = (results[BATCH] - results[HETEROGENEOUS]) / results[BATCH] * 100
    print(f"heterogeneous vs batch improvement: {impr:.1f}% "
          f"(paper reports 4-15% at ORNL scale)")


if __name__ == "__main__":
    main()
