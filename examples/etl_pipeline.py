"""The paper's headline experiment as a runnable example: heterogeneous
(shared-pool) vs batch (static-partition) execution of mixed join+sort
pipelines on one resource pool — expect the heterogeneous policy to win
(paper: 4-15%).

Run with several host devices to see real interleaving:
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
      PYTHONPATH=src python examples/etl_pipeline.py
"""
import time

import jax
import numpy as np

from repro.core import (BATCH, HETEROGENEOUS, LiveScheduler, PilotDescription,
                        PilotManager, TaskDescription)
from repro.dataframe import ops_dist as D

ROWS = 20_000


def sort_payload(comm):
    rng = np.random.default_rng(1)
    data = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32)}
    t = D.shard_table(comm, data, ROWS // comm.size * 2 + 64)
    out, _ = D.make_dist_sort(comm.mesh, "k")(t)
    jax.block_until_ready(out.columns["k"])
    time.sleep(1.0)    # simulated residual work: this container has ONE core,
                       # so cross-task parallelism is demonstrated via sleep
    return "sorted"


def join_payload(comm):
    rng = np.random.default_rng(2)
    cap = ROWS // comm.size * 2 + 64
    a = D.shard_table(comm, {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
                             "v": rng.normal(size=ROWS).astype(np.float32)}, cap)
    b = D.shard_table(comm, {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
                             "w": rng.normal(size=ROWS).astype(np.float32)}, cap)
    out, _ = D.make_dist_join(comm.mesh, "k", out_factor=3.0)(a, b)
    jax.block_until_ready(out.columns["k"])
    time.sleep(3.0)    # joins are the long pole (see sort_payload note)
    return "joined"


def mix(n_dev):
    per = max(n_dev // 2, 1)
    descs = []
    for i in range(2):
        descs.append(TaskDescription(name=f"join{i}", ranks=per,
                                     fn=join_payload, tags={"pipeline": "join"}))
    for i in range(4):
        descs.append(TaskDescription(name=f"sort{i}", ranks=per,
                                     fn=sort_payload, tags={"pipeline": "sort"}))
    return descs


def main():
    n = len(jax.devices())
    results = {}
    for policy in (HETEROGENEOUS, BATCH):
        pm = PilotManager()
        pilot = pm.submit_pilot(PilotDescription(n_devices=n))
        sched = LiveScheduler(pilot.resource_manager, policy)
        rep = sched.run(mix(n), timeout=900)
        bad = [t for t in rep.tasks if t.state.value != "DONE"]
        assert not bad, [(t.desc.name, t.error) for t in bad]
        results[policy] = rep.makespan
        print(f"[{policy:>13s}] makespan {rep.makespan:.2f}s  "
              f"(comm-build total {rep.overhead_total * 1e3:.1f}ms)")
    impr = (results[BATCH] - results[HETEROGENEOUS]) / results[BATCH] * 100
    print(f"heterogeneous vs batch improvement: {impr:.1f}% "
          f"(paper reports 4-15% at ORNL scale)")


if __name__ == "__main__":
    main()
