"""The paper's headline experiment as a runnable example: heterogeneous
(shared-pool) vs batch (static-partition) execution of two MPMD pipelines —
a join DAG and a sort DAG — on one resource pool, with *continuous DAG
release*: each stage is submitted the moment its own deps complete, so a
freed device immediately backfills work from any pipeline (expect the
heterogeneous policy to win; paper: 4-15%).

Two live backends share the identical scheduler core and payloads:

  thread (default) — every task in this process, one worker thread each:
    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/etl_pipeline.py

  process — the paper's multi-node mode: one fresh interpreter per "node",
  each owning its own host devices; the final merge stage's ranks span both
  worker processes and aggregate through the cross-process communicator:
    PYTHONPATH=src python examples/etl_pipeline.py --backend process
"""
import argparse
import time

import numpy as np


ROWS = 20_000


def _local(comm):
    """Per-node view of the communicator: under ProcessExecutor the dataframe
    ops run on this worker's private sub-mesh; under ThreadExecutor the task's
    whole communicator IS local."""
    return getattr(comm, "local_comm", comm)


def sort_payload(comm, *_deps):
    import jax
    from repro.dataframe import ops_dist as D
    lc = _local(comm)
    rng = np.random.default_rng(1)
    data = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32)}
    t = D.shard_table(lc, data, ROWS // lc.size * 2 + 64)
    out, _ = D.make_dist_sort(lc.mesh, "k")(t)
    jax.block_until_ready(out.columns["k"])
    time.sleep(1.0)    # simulated residual work: this container has ONE core,
                       # so cross-task parallelism is demonstrated via sleep
    return "sorted"


def join_payload(comm, *_deps):
    import jax
    from repro.dataframe import ops_dist as D
    lc = _local(comm)
    rng = np.random.default_rng(2)
    cap = ROWS // lc.size * 2 + 64
    a = D.shard_table(lc, {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
                           "v": rng.normal(size=ROWS).astype(np.float32)}, cap)
    b = D.shard_table(lc, {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
                           "w": rng.normal(size=ROWS).astype(np.float32)}, cap)
    out, _ = D.make_dist_join(lc.mesh, "k", out_factor=3.0)(a, b)
    jax.block_until_ready(out.columns["k"])
    time.sleep(3.0)    # joins are the long pole (see sort_payload note)
    return "joined"


def merge_payload(comm, *deps):
    """Full-width stage: under the process backend its ranks span every
    worker, so each node sorts its local shard and the per-node row counts
    are combined through the cross-process communicator (the paper's
    heterogeneous MPI_Comm across nodes)."""
    import jax
    from repro.dataframe import ops_dist as D
    lc = _local(comm)
    rng = np.random.default_rng(3)
    data = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32)}
    t = D.shard_table(lc, data, ROWS // lc.size * 2 + 64)
    out, _ = D.make_dist_sort(lc.mesh, "k")(t)
    jax.block_until_ready(out.columns["k"])
    local_rows = int(np.asarray(out.nrows).sum())
    if hasattr(comm, "allgather"):          # ProcessExecutor: one value/node
        total = sum(comm.allgather(local_rows))
    else:
        total = local_rows
    return f"merged({total} rows over {comm.size} ranks)"


def build_pipelines(n_dev, full_width=True):
    """Two DAG pipelines: 'join' is one heavy stage plus a cheap dependent
    summarize stage; 'sort' is a chain of sorts feeding a full-width merge.
    Under continuous release the summarize stage starts the moment its join
    finishes — while the other pipeline's sorts are still running (no wave
    barrier).

    ``full_width=False`` caps the merge at half the pool: a BATCH run's
    static partition can never host a task wider than its own share — the
    paper's rigidity argument against static partitioning, and exactly why
    the heterogeneous shared pool CAN run the cross-node merge."""
    from repro.core import Pipeline
    per = max(n_dev // 2, 1)
    merge_ranks = n_dev if full_width else per
    join = Pipeline("join")
    join.add("join0", ranks=per, fn=join_payload)
    join.add("join1", ranks=per, fn=join_payload)
    join.add("summarize", ranks=per,
             fn=lambda comm, *deps: f"summary({','.join(map(str, deps))})",
             deps=["join0", "join1"])
    sort = Pipeline("sort")
    sort.add("sort0", ranks=per, fn=sort_payload)
    sort.add("sort1", ranks=per, fn=sort_payload)
    sort.add("sort2", ranks=per, fn=sort_payload, deps=["sort0"])
    sort.add("sort3", ranks=per, fn=sort_payload, deps=["sort1"])
    sort.add("merge", ranks=merge_ranks, fn=merge_payload,
             deps=["sort2", "sort3"])
    return [join, sort]


def print_timeline(report, t0):
    for e in report.trace:
        if e.kind in ("dispatch", "done"):
            print(f"    t={e.t - t0:6.2f}s {e.kind:>8s} {e.task:<16s} "
                  f"ranks={e.ranks}")


def _run_policies(n, make_executor, make_rm, placement="spread",
                  work_stealing=False):
    from repro.core import BATCH, HETEROGENEOUS, run_pipelines
    results = {}
    for policy in (HETEROGENEOUS, BATCH):
        ex = make_executor()
        try:
            t0 = time.perf_counter()
            # full_width=False keeps the two policies on IDENTICAL
            # workloads (and a batch partition cannot host a full-pool
            # task anyway); the full-width cross-node merge is shown
            # separately below
            pipes = build_pipelines(n, full_width=False)
            res, rep = run_pipelines(pipes, make_rm(ex),
                                     policy=policy, timeout=900, executor=ex,
                                     placement=placement,
                                     work_stealing=work_stealing)
            assert res[("join", "summarize")].startswith("summary")
            assert res[("sort", "merge")].startswith("merged")
        finally:
            if hasattr(ex, "shutdown"):
                ex.shutdown()
        results[policy] = rep.makespan
        stolen = rep.events("steal")
        extra = f", {len(stolen)} steals" if stolen else ""
        print(f"[{policy:>13s}] makespan {rep.makespan:.2f}s  "
              f"(comm-build total {rep.overhead_total * 1e3:.1f}ms, "
              f"{len(rep.events('dispatch'))} dispatches, "
              f"placement={placement}{extra})")
        print_timeline(rep, t0)
    impr = (results[BATCH] - results[HETEROGENEOUS]) / results[BATCH] * 100
    print(f"heterogeneous vs batch improvement: {impr:.1f}% "
          f"(paper reports 4-15% at ORNL scale)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", choices=("thread", "process"),
                    default="thread")
    ap.add_argument("--workers", type=int, default=2,
                    help="process backend: worker interpreters (nodes)")
    ap.add_argument("--devices-per-worker", type=int, default=2)
    ap.add_argument("--placement", choices=("spread", "pack"),
                    default="spread",
                    help="pack keeps a fitting task's ranks on one worker "
                         "(process backend: no hub collectives)")
    ap.add_argument("--work-stealing", action="store_true",
                    help="batch policy: backlogged partitions lease idle "
                         "devices from sibling partitions")
    args = ap.parse_args()

    if args.backend == "thread":
        import jax
        from repro.core import (PilotDescription, PilotManager,
                                ThreadExecutor)
        n = len(jax.devices())
        _run_policies(
            n,
            make_executor=lambda: ThreadExecutor(),
            make_rm=lambda ex: PilotManager().submit_pilot(
                PilotDescription(n_devices=n)).resource_manager,
            placement=args.placement, work_stealing=args.work_stealing)
    else:
        from repro.core import (ProcessExecutor, SchedulerSession,
                                TaskDescription)
        n = args.workers * args.devices_per_worker
        print(f"process backend: {args.workers} workers x "
              f"{args.devices_per_worker} devices")
        # one executor (and its worker processes) per policy run keeps the
        # comparison fair: both start with cold per-task caches
        _run_policies(
            n,
            make_executor=lambda: ProcessExecutor(
                n_workers=args.workers,
                devices_per_worker=args.devices_per_worker,
                build_comm=True).start(),
            make_rm=lambda ex: ex.resource_manager(),
            placement=args.placement, work_stealing=args.work_stealing)
        # the paper's multi-node headline: ONE task whose communicator spans
        # every worker process — per-node sub-mesh sorts combined through
        # the cross-process allgather
        ex = ProcessExecutor(n_workers=args.workers,
                             devices_per_worker=args.devices_per_worker,
                             build_comm=True).start()
        try:
            sess = SchedulerSession(ex, ex.resource_manager())
            rep = sess.run([TaskDescription(name="merge_all", ranks=n,
                                            fn=merge_payload,
                                            tags={"pipeline": "demo"})],
                           timeout=300)
            task = rep.tasks[0]
            spans = {d.worker for d in task.devices}
            print(f"cross-node merge over {len(spans)} workers: "
                  f"{task.result}")
        finally:
            ex.shutdown()


if __name__ == "__main__":
    main()
