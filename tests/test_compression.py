"""int8+error-feedback gradient compression: bounded error, exact mean under
shared scale, convergence on a quadratic with EF."""
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.distributed.compression import dequantize_int8, quantize_int8


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 2000), st.integers(0, 10_000))
def test_quantize_roundtrip_error_bounded(n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n,)).astype(np.float32)) * 3.0
    q, s, meta = quantize_int8(x)
    back = dequantize_int8(q, s, meta)
    # error bounded by scale/2 per block
    err = np.abs(np.asarray(back - x))
    bound = np.repeat(np.asarray(s) / 2 + 1e-6, 256)[:n]
    assert (err <= bound + 1e-6).all()


@pytest.mark.integration
def test_compressed_mean_subprocess():
    from tests._subproc import run_with_devices
    out = run_with_devices(r"""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum_mean
mesh = jax.make_mesh((4,), ("data",), axis_types=(jax.sharding.AxisType.Auto,))
x = jax.random.normal(jax.random.key(0), (4, 5000))
@partial(jax.shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"),
         check_vma=False)
def f(xl):
    m, e = compressed_psum_mean(xl[0], "data")
    return m[None]
got = np.asarray(f(x))[0]
want = np.asarray(jnp.mean(x, 0))
rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
assert rel < 0.02, rel
print("MEAN_OK", rel)
""", n_devices=4)
    assert "MEAN_OK" in out


def test_error_feedback_convergence():
    """SGD on a quadratic where every 'gradient' passes through quantization
    with error feedback must still converge (EF property)."""
    w = jnp.asarray([4.0, -7.0, 2.5])
    err = jnp.zeros_like(w)
    lr = 0.05
    for _ in range(400):
        g = 2 * w
        q, s, meta = quantize_int8(g + err)
        g_hat = dequantize_int8(q, s, meta)
        err = (g + err) - g_hat
        w = w - lr * g_hat
    assert float(jnp.abs(w).max()) < 0.05
