"""Elastic scaling: checkpoint written on one mesh restores onto a DIFFERENT
mesh shape (pool shrink/grow recovery), and training continues identically."""
import pytest

from tests._subproc import run_with_devices

SNIPPET = r"""
import dataclasses, tempfile, numpy as np, jax
from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh
from repro.train.data import SyntheticCorpus
from repro.train.trainer import Trainer

cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), n_layers=2)
shape = ShapeConfig("t", "train", 32, 8)
ckpt = tempfile.mkdtemp()

# train 6 steps on a (4,2) mesh, checkpoint
mesh_a = make_local_mesh(4, 2)
tr_a = Trainer(cfg, mesh_a, ParallelConfig(), shape, ckpt_dir=ckpt, ckpt_every=6)
corpus = SyntheticCorpus(cfg.vocab_size, 0)
state_a, _ = tr_a.fit(corpus.batches(8, 32, 6), steps=6, log_every=0)
ref_norm = np.asarray(state_a.params["final_norm"])

# ELASTIC: restore the same checkpoint onto a (2,2) mesh (pool shrank)
mesh_b = make_local_mesh(2, 2)
tr_b = Trainer(cfg, mesh_b, ParallelConfig(), shape, ckpt_dir=ckpt)
state_b = tr_b.maybe_restore()
assert state_b is not None and state_b.step == 6
np.testing.assert_array_equal(np.asarray(state_b.params["final_norm"]), ref_norm)
assert state_b.params["final_norm"].sharding.mesh.shape == mesh_b.shape

# ...and onto a (8,1) mesh (pool regrew, different topology)
mesh_c = make_local_mesh(8, 1)
tr_c = Trainer(cfg, mesh_c, ParallelConfig(), shape, ckpt_dir=ckpt)
state_c = tr_c.maybe_restore()
np.testing.assert_array_equal(np.asarray(state_c.params["final_norm"]), ref_norm)

# training continues on the new mesh
state_c2, losses = tr_c.fit(corpus.batches(8, 32, 2), steps=2, state=state_c,
                            log_every=0)
assert state_c2.step == 8 and all(np.isfinite(l) for l in losses)
print("ELASTIC_OK")
"""


@pytest.mark.integration
def test_elastic_restore_across_meshes():
    out = run_with_devices(SNIPPET, n_devices=8, timeout=900)
    assert "ELASTIC_OK" in out


MULTIDEV_TRAIN = r"""
import dataclasses, numpy as np, jax
from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh
from repro.train.data import SyntheticCorpus
from repro.train.trainer import Trainer

# DP x TP on a real (2,2) mesh must match single-device training numerics
cfg = dataclasses.replace(reduced(get_config("granite-3-8b")), n_layers=2)
shape = ShapeConfig("t", "train", 32, 4)
corpus = SyntheticCorpus(cfg.vocab_size, 0)
batches = list(corpus.batches(4, 32, 4))

mesh1 = make_local_mesh(1, 1)
tr1 = Trainer(cfg, mesh1, ParallelConfig(), shape)
s1, l1 = tr1.fit(iter(batches), steps=4, log_every=0)

mesh4 = make_local_mesh(2, 2)
tr4 = Trainer(cfg, mesh4, ParallelConfig(), shape)
s4, l4 = tr4.fit(iter(batches), steps=4, log_every=0)

np.testing.assert_allclose(l1, l4, atol=2e-3)
np.testing.assert_allclose(np.asarray(s1.params["final_norm"]),
                           np.asarray(s4.params["final_norm"]), atol=2e-3)
print("DPTP_MATCH_OK", l1[-1], l4[-1])
"""


@pytest.mark.integration
def test_dp_tp_training_matches_single_device():
    out = run_with_devices(MULTIDEV_TRAIN, n_devices=4, timeout=900)
    assert "DPTP_MATCH_OK" in out
