"""Crash-safe checkpoint-resume and the task result cache (PR 10).

Three layers, mirroring how the feature is built:

* ``train.checkpoint`` commit protocol — atomic manifest/LATEST finalize,
  torn-tail fallback, structured restore errors, and the
  ``CheckpointContext`` attempt-lineage reads (unit, tier-1);
* scheduler integration — a retry on the thread executor resumes from the
  doomed attempt's last durable step, and identical resubmitted tasks
  complete straight from the result cache, bit-identically (tier-1);
* process-executor integration — a worker SIGKILLed mid-task loses real
  state, yet the retry on the surviving worker restores the checkpoint
  written before the kill (``integration`` mark, CI proc job).
"""
import os
import signal
import subprocess
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ProcessExecutor, ResourceManager, SchedulerSession, TaskDescription,
    TaskState, ThreadExecutor,
)
from repro.core.executors import SimOptions, serialize
from repro.core.scheduler import simulate
from repro.train.checkpoint import (
    CheckpointContext, CheckpointError, completed_steps, latest_step,
    restore, save,
)

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle

    # ship this module's payload functions by value: a worker process has no
    # way to import the test module
    cloudpickle.register_pickle_by_value(sys.modules[__name__])

needs_cloudpickle = pytest.mark.skipif(
    not serialize.HAVE_CLOUDPICKLE,
    reason="cloudpickle needed to ship test-local payload functions")

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ---------------------------------------------------------------------------
# commit protocol units
# ---------------------------------------------------------------------------
def _tree(scale=1.0):
    return {"w": np.arange(4.0) * scale, "opt": {"m": np.ones(2) * scale}}


def test_save_commits_atomically_and_latest_is_monotonic(tmp_path):
    save(tmp_path, 5, _tree(), async_=False)
    # an out-of-order (older) save lands as a step but must NOT move LATEST
    # backwards — e.g. a straggling async writer of a step already superseded
    save(tmp_path, 3, _tree(0.5), async_=False)
    assert (tmp_path / "LATEST").read_text().strip() == "5"
    assert completed_steps(tmp_path) == [3, 5]
    assert latest_step(tmp_path) == 5
    # tmp-file finalize leaves no droppings behind
    assert not [p for p in tmp_path.rglob(".*tmp*")]


def test_latest_validates_and_falls_back_to_newest_complete(tmp_path):
    save(tmp_path, 1, _tree(), async_=False)
    save(tmp_path, 2, _tree(2.0), async_=False)
    # torn LATEST (garbage bytes): fall back to the manifest scan
    (tmp_path / "LATEST").write_text("garb\x00age")
    assert latest_step(tmp_path) == 2
    # LATEST pointing at a step whose leaf vanished: also fall back
    (tmp_path / "LATEST").write_text("2")
    (tmp_path / "step_00000002" / "w.npy").unlink()
    assert latest_step(tmp_path) == 1
    assert completed_steps(tmp_path) == [1]
    # and restore of the half-missing step refuses with a structured error
    with pytest.raises(CheckpointError, match="step 2"):
        restore(tmp_path, 2, _tree())


def test_restore_names_missing_leaf(tmp_path):
    save(tmp_path, 0, {"w": np.arange(3.0)}, async_=False)
    with pytest.raises(CheckpointError, match="opt/m"):
        restore(tmp_path, 0, {"w": np.zeros(3), "opt": {"m": np.zeros(2)}})
    with pytest.raises(CheckpointError, match="no complete checkpoint"):
        restore(tmp_path, 9, {"w": np.zeros(3)})


def test_restore_dtype_cast_and_scalar_leaves(tmp_path):
    tree = {"w": np.arange(4, dtype=np.float64), "step": 7, "lr": 0.1}
    save(tmp_path, 0, tree, async_=False)
    like = {"w": np.zeros(4, dtype=np.float32), "step": 0, "lr": 0.0}
    got = restore(tmp_path, 0, like)
    assert got["w"].dtype == np.float32          # cast to `like`'s dtype
    assert np.allclose(got["w"], np.arange(4))
    assert int(got["step"]) == 7                 # scalar leaves: no dtype
    assert float(got["lr"]) == pytest.approx(0.1)   # guard crash (satellite)
    same = restore(tmp_path, 0, {"w": np.zeros(4, dtype=np.float64),
                                 "step": 0, "lr": 0.0})
    assert same["w"].dtype == np.float64


def test_plain_save_restores_through_jax_tree_path(tmp_path):
    import jax.numpy as jnp
    save(tmp_path, 0, _tree(3.0), async_=False)     # pure-numpy writer
    like = {"w": jnp.zeros(4, jnp.float32), "opt": {"m": jnp.zeros(2)}}
    got = restore(tmp_path, 0, like)                # jax-flatten reader
    assert got["w"].dtype == np.float32             # cast to like's dtype
    assert np.allclose(np.asarray(got["w"]), np.arange(4.0) * 3.0)


def test_sigkill_at_commit_boundary_leaves_restorable_step(tmp_path):
    """A process killed after writing step 1's leaves but BEFORE its
    manifest commits must leave step 0 fully restorable and step 1
    invisible — the manifest is the commit point."""
    snippet = (
        "import os, signal, sys\n"
        "import numpy as np\n"
        "from repro.train import checkpoint as ck\n"
        "root = sys.argv[1]\n"
        "ck.save(root, 0, {'w': np.arange(4.0)}, async_=False)\n"
        "orig = ck._atomic_write_text\n"
        "def dying(path, text):\n"
        "    if path.name == 'manifest.json':\n"
        "        os.kill(os.getpid(), signal.SIGKILL)\n"
        "    orig(path, text)\n"
        "ck._atomic_write_text = dying\n"
        "ck.save(root, 1, {'w': np.arange(4.0) * 2}, async_=False)\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", snippet, str(tmp_path)],
                       env=env, capture_output=True, text=True, timeout=240)
    assert r.returncode == -signal.SIGKILL, r.stderr
    # the kill really happened mid-save: step 1's leaves are on disk...
    assert (tmp_path / "step_00000001" / "w.npy").exists()
    # ...but the step never committed, and resume lands on step 0
    assert latest_step(tmp_path) == 0
    assert completed_steps(tmp_path) == [0]
    got = restore(tmp_path, 0, {"w": np.zeros(4)})
    assert np.allclose(got["w"], np.arange(4.0))


def test_context_reads_across_attempts_writes_only_its_own(tmp_path):
    a0 = CheckpointContext(tmp_path, attempt="a0")
    a0.save(0, {"acc": np.full(2, 0.0)})
    a0.save(1, {"acc": np.full(2, 1.0)})
    a1 = CheckpointContext(tmp_path, attempt="a1")
    assert a1.latest() == 1                       # sees the doomed primary's
    got = a1.restore(1, {"acc": np.zeros(2)})     # durable progress...
    assert np.allclose(got["acc"], 1.0)
    assert a1.resumed_from_step == 1
    a1.save(2, {"acc": np.full(2, 2.0)})
    # ...but writes land only in a1's own dir (no cross-attempt races)
    assert completed_steps(a0.dir) == [0, 1]
    assert completed_steps(a1.dir) == [2]
    assert a0.latest() == 2                       # lineage-wide view
    # a different part split is a different scope: conservatively fresh
    assert CheckpointContext(tmp_path, attempt="a0",
                             part=0, n_parts=2).latest() is None


# ---------------------------------------------------------------------------
# scheduler integration: thread executor (tier-1)
# ---------------------------------------------------------------------------
def test_thread_retry_resumes_from_last_durable_step(tmp_path):
    executed = []

    def pay(comm, n_steps=6):
        c = comm.checkpoint
        assert c is not None
        acc, start = np.zeros(2), 0
        last = c.latest()
        if last is not None:
            acc = c.restore(last, {"acc": acc})["acc"]
            start = last + 1
        for s in range(start, n_steps):
            executed.append(s)
            acc = acc + s
            c.save(s, {"acc": acc})
            if s == 2 and c.attempt == "a0":
                raise RuntimeError("dies after step 2 committed")
        return acc

    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["d0"]), tick=0.01,
                            ckpt_root=str(tmp_path))
    rep = sess.run([TaskDescription(name="t", ranks=1, fn=pay, max_retries=2,
                                    tags={"pipeline": "p"})], timeout=60)
    task = rep.tasks[0]
    assert task.state == TaskState.DONE
    assert rep.n_retries == 1
    # the retry restored step 2 and ran 3..5 — no step executed twice
    assert executed == [0, 1, 2, 3, 4, 5]
    assert task.resumed_from_step == 2
    assert np.allclose(task.result, sum(range(6)))
    resumes = rep.events("resume")
    assert len(resumes) == 1 and resumes[0].value == 2.0
    # evidence also rides the terminal event's data dict (trace_summary path)
    done = rep.events("done")[0]
    assert done.data["resumed_from_step"] == 2


def test_no_ckpt_root_means_no_context(tmp_path):
    seen = []

    def pay(comm):
        seen.append(comm.checkpoint)
        return 1

    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["d0"]), tick=0.01)
    rep = sess.run([TaskDescription(name="t", ranks=1, fn=pay,
                                    tags={"pipeline": "p"})], timeout=60)
    assert rep.tasks[0].state == TaskState.DONE
    assert seen == [None]
    assert not rep.events("resume")


def test_env_knob_binds_ckpt_root(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_CKPT_DIR", str(tmp_path))

    def pay(comm):
        comm.checkpoint.save(0, {"x": np.ones(1)})
        return 1

    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["d0"]), tick=0.01)
    rep = sess.run([TaskDescription(name="t", ranks=1, fn=pay,
                                    tags={"pipeline": "p"})], timeout=60)
    uid = rep.tasks[0].uid
    assert latest_step(tmp_path / f"t{uid}" / "p0-of-1" / "a0") == 0


def test_virtual_clock_resume_model(monkeypatch, tmp_path):
    """Sim parity: with a checkpoint namespace bound and
    ``ckpt_period_s`` set, retries of injected failures bank whole-period
    progress and run only the remainder — same seed without the model
    re-runs from scratch and takes at least as long."""
    monkeypatch.setenv("REPRO_CKPT_DIR", str(tmp_path))
    descs = [TaskDescription(name=f"t{i}", ranks=1, fn=None,
                             duration_model=lambda r: 10.0, max_retries=8,
                             tags={"pipeline": "p"}) for i in range(4)]
    base = dict(noise=0.0, overhead_model=lambda r: 0.0,
                failure_prob=0.4, seed=3)
    cold = simulate(descs, 2, SimOptions(**base))
    warm = simulate(descs, 2, SimOptions(**base, ckpt_period_s=2.0))
    assert all(t.state == TaskState.DONE for t in warm.tasks)
    # same seed -> same failure pattern; this seed produces retries
    assert warm.n_retries == cold.n_retries > 0
    resumes = warm.events("resume")
    assert resumes and all(e.value > 0 for e in resumes)
    assert not cold.events("resume")
    assert warm.makespan < cold.makespan


# ---------------------------------------------------------------------------
# result cache (tier-1, thread executor)
# ---------------------------------------------------------------------------
# the cacheable payload lives in an importable helper module: this test
# module is pickled BY VALUE (for the proc payloads below), and by-value
# function pickles are not byte-stable across intervening imports — their
# cache keys would drift.  By-reference pickles (importable module fns,
# the realistic production shape) digest deterministically.
from _ckpt_payloads import counted as _counted  # noqa: E402


def _runs(marker):
    return len(Path(marker).read_text().splitlines()) \
        if Path(marker).exists() else 0


def _cache_session(cache):
    return SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["d0"]), tick=0.01,
                            result_cache=cache)


def test_result_cache_hit_is_bit_identical_and_skips_recompute(tmp_path):
    cache, marker = str(tmp_path / "cache"), str(tmp_path / "runs.txt")
    desc = lambda: TaskDescription(name="c", ranks=1, fn=_counted,  # noqa: E731
                                   args=(marker,), tags={"pipeline": "p"})
    rep1 = _cache_session(cache).run([desc()], timeout=60)
    assert rep1.tasks[0].state == TaskState.DONE
    assert not rep1.tasks[0].cache_hit and _runs(marker) == 1
    assert not rep1.events("cache_hit")

    rep2 = _cache_session(cache).run([desc()], timeout=60)
    t2 = rep2.tasks[0]
    assert t2.state == TaskState.DONE and t2.cache_hit
    assert _runs(marker) == 1                      # payload never re-ran
    assert t2.result.tobytes() == rep1.tasks[0].result.tobytes()
    assert t2.result.dtype == rep1.tasks[0].result.dtype
    hits = rep2.events("cache_hit")
    assert len(hits) == 1
    assert rep2.events("done")[0].data.get("cache_hit") is True
    # hits never dispatch: no executor launch for the cached task
    assert not rep2.events("dispatch")

    # different arguments -> different key -> recompute
    rep3 = _cache_session(cache).run(
        [TaskDescription(name="c", ranks=1, fn=_counted,
                         args=(marker,), kwargs={"scale": 3.0},
                         tags={"pipeline": "p"})], timeout=60)
    assert not rep3.tasks[0].cache_hit and _runs(marker) == 2


def test_result_cache_env_knob_and_zero_disables(tmp_path, monkeypatch):
    cache, marker = str(tmp_path / "cache"), str(tmp_path / "runs.txt")
    desc = lambda: TaskDescription(name="c", ranks=1, fn=_counted,  # noqa: E731
                                   args=(marker,), tags={"pipeline": "p"})

    def run_with_env(val):
        monkeypatch.setenv("REPRO_RESULT_CACHE", val)
        sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                                ResourceManager(["d0"]), tick=0.01)
        return sess.run([desc()], timeout=60)

    rep1 = run_with_env(cache)
    assert _runs(marker) == 1 and not rep1.tasks[0].cache_hit
    rep2 = run_with_env(cache)                     # env-bound cache hits
    assert _runs(marker) == 1 and rep2.tasks[0].cache_hit
    rep3 = run_with_env("0")                       # "0" reverts to recompute
    assert _runs(marker) == 2 and not rep3.tasks[0].cache_hit
    assert not rep3.events("cache_hit")


def test_virtual_clock_never_caches(tmp_path):
    """The sim is not wall-clock: identical descs must re-simulate, never
    complete from a result cache written by a live run."""
    from repro.core.executors import VirtualClockExecutor
    ex = VirtualClockExecutor(SimOptions(noise=0.0,
                                         overhead_model=lambda r: 0.0))
    sess = SchedulerSession(ex, ResourceManager([0]),
                            result_cache=str(tmp_path))
    rep = sess.run([TaskDescription(name="t", ranks=1, fn=None,
                                    duration_model=lambda r: 1.0,
                                    tags={"pipeline": "p"})])
    assert rep.tasks[0].state == TaskState.DONE
    assert not rep.events("cache_hit")
    assert not list(Path(tmp_path).glob("*.pkl"))


# ---------------------------------------------------------------------------
# process-executor integration: real SIGKILL, real resume
# ---------------------------------------------------------------------------
def _ckpt_steps(comm, n_steps=8, step_s=0.25):
    c = comm.checkpoint
    acc, start = np.zeros(1), 0
    last = c.latest() if c is not None else None
    if last is not None:
        acc = c.restore(last, {"acc": acc})["acc"]
        start = last + 1
    executed = 0
    for s in range(start, n_steps):
        time.sleep(step_s)
        acc = acc + s
        c.save(s, {"acc": acc})
        executed += 1
    return {"executed": executed, "start": start, "acc": float(acc[0])}


@needs_cloudpickle
@pytest.mark.integration
def test_proc_sigkill_midtask_retry_resumes(tmp_path):
    """SIGKILL the worker running a stepped task partway through: the retry
    on the surviving worker must restore the steps the dead attempt durably
    committed and re-execute strictly fewer than the total."""
    n_steps, step_s = 8, 0.25
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, tick=0.005,
                         heartbeat_interval=0.2) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02,
                                ckpt_root=str(tmp_path))
        sess.submit([TaskDescription(
            name="steps", ranks=1, fn=_ckpt_steps,
            kwargs={"n_steps": n_steps, "step_s": step_s},
            max_retries=2, tags={"pipeline": "p"})])
        # let a few steps commit, then kill the worker that owns the task
        time.sleep(step_s * (n_steps // 2) + 0.4)
        victim = sess.tasks[0].devices[0].worker
        ex.kill_worker(victim, signal.SIGKILL)
        rep = sess.drain(timeout=180).close()
    task = rep.tasks[0]
    assert task.state == TaskState.DONE
    assert rep.n_retries >= 1
    assert task.resumed_from_step > 0              # acceptance: resume evid.
    assert task.result["start"] == task.resumed_from_step + 1
    assert task.result["executed"] < n_steps       # strictly fewer re-runs
    assert task.result["acc"] == float(sum(range(n_steps)))
    resumes = rep.events("resume")
    assert resumes and resumes[0].value == float(task.resumed_from_step)
