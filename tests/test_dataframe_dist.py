"""Distributed dataframe ops on a REAL multi-device mesh (subprocess with 4
host devices): dist sort / join / groupby vs numpy oracles."""
import pytest

from tests._subproc import run_with_devices

SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import build_communicator
from repro.dataframe import ops_dist as D
from repro.dataframe import reference as R

comm = build_communicator(jax.devices(), axes=("df",))
rng = np.random.default_rng(42)
n = 1200
data = {"k": rng.integers(0, 300, n).astype(np.int32),
        "v": rng.normal(size=n).astype(np.float32)}
t = D.shard_table(comm, data, capacity_per_rank=700)

out, ovf = D.make_dist_sort(comm.mesh, "k")(t)
got = D.collect_table(out)
assert not bool(ovf)
assert sorted(got["k"].tolist()) == sorted(data["k"].tolist())
assert (np.diff(got["k"]) >= 0).all(), "not globally sorted"
ref = R.ref_sort(data, "k")
assert np.allclose(np.sort(got["v"]), np.sort(ref["v"]))
print("SORT_OK")

data2 = {"k": rng.integers(0, 300, 900).astype(np.int32),
         "w": rng.normal(size=900).astype(np.float32)}
t2 = D.shard_table(comm, data2, capacity_per_rank=700)
out, ovf = D.make_dist_join(comm.mesh, "k", out_factor=8.0)(t, t2)
got = D.collect_table(out)
ref = R.ref_join_inner(data, data2, "k")
assert not bool(ovf)
a = R.sorted_rows(got); b = R.sorted_rows(ref)
assert a.shape == b.shape and np.allclose(a, b)
print("JOIN_OK", len(got["k"]))

out, ovf = D.make_dist_groupby_sum(comm.mesh, "k", ["v"])(t)
got = D.collect_table(out)
ref = R.ref_groupby_sum(data, "k", ["v"])
assert len(got["k"]) == len(ref["k"])
o = np.argsort(got["k"])
assert np.allclose(got["v"][o], ref["v"][np.argsort(ref["k"])], atol=1e-4)
print("GROUPBY_OK")
"""


@pytest.mark.integration
def test_dist_ops_4dev():
    out = run_with_devices(SNIPPET, n_devices=4)
    assert "SORT_OK" in out and "JOIN_OK" in out and "GROUPBY_OK" in out


SHUFFLE_SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import build_communicator
from repro.dataframe import ops_dist as D

comm = build_communicator(jax.devices(), axes=("df",))
rng = np.random.default_rng(7)
n = 800
data = {"k": rng.integers(0, 1000, n).astype(np.int32)}
t = D.shard_table(comm, data, capacity_per_rank=400)
# route row to rank (k % 4); conservation + placement checks
target_np = (data["k"] % 4).astype(np.int32)
# build the global padded target vector matching the shard layout
per = [n // 4] * 4
tgt = np.zeros((4, 400), np.int32)
offs = np.cumsum([0] + per)
for r in range(4):
    tgt[r, :per[r]] = target_np[offs[r]:offs[r+1]]
from jax.sharding import NamedSharding, PartitionSpec as P
tj = jax.device_put(tgt.reshape(-1), NamedSharding(comm.mesh, P("df")))
out, ovf = D.make_shuffle(comm.mesh)(t, tj)
assert not bool(ovf)
got = D.collect_table(out)
assert sorted(got["k"].tolist()) == sorted(data["k"].tolist()), "rows lost"
# every row landed on rank k%4
nrows = np.asarray(out.nrows)
cols = np.asarray(out.columns["k"]).reshape(4, -1)
for r in range(4):
    kk = cols[r, :nrows[r]]
    assert (kk % 4 == r).all()
print("SHUFFLE_OK")
"""


@pytest.mark.integration
def test_shuffle_conservation_and_placement():
    out = run_with_devices(SHUFFLE_SNIPPET, n_devices=4)
    assert "SHUFFLE_OK" in out


OVERFLOW_SNIPPET = r"""
import numpy as np, jax
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import build_communicator
from repro.dataframe import ops_dist as D

comm = build_communicator(jax.devices(), axes=("df",))
rng = np.random.default_rng(11)
n = 800
# every row targets rank 0: each rank sends 200 rows to one destination,
# but slack=1.0 gives send_cap = 400 * 1.0 // 4 + 8 = 108 < 200 — the
# counts > send_cap overflow path actually trips
data = {"k": np.zeros(n, np.int32),
        "v": rng.normal(size=n).astype(np.float32)}
t = D.shard_table(comm, data, capacity_per_rank=400)
tj = jax.device_put(np.zeros(4 * 400, np.int32),
                    NamedSharding(comm.mesh, P("df")))

out, ovf = D.make_shuffle(comm.mesh, slack=1.0)(t, tj)
assert bool(ovf), "overflow flag must trip when counts > send_cap"
print("FLAG_OK")

try:
    D.make_shuffle(comm.mesh, slack=1.0, on_overflow="raise")(t, tj)
except D.ShuffleOverflow as e:
    assert e.op == "shuffle" and e.slack == 1.0
    print("RAISE_OK")
else:
    raise AssertionError("on_overflow='raise' did not raise")

# dist_join funnels both sides through the same packing stage
try:
    D.make_dist_join(comm.mesh, "k", slack=1.0, on_overflow="raise")(t, t)
except D.ShuffleOverflow as e:
    assert e.op == "dist_join"
    print("JOIN_RAISE_OK")
else:
    raise AssertionError("dist_join on_overflow='raise' did not raise")

# ample slack: same workload passes and returns ovf=False
out, ovf = D.make_shuffle(comm.mesh, slack=4.0, on_overflow="raise")(t, tj)
assert not bool(ovf)
print("CLEAN_OK")
"""


@pytest.mark.integration
def test_shuffle_overflow_is_observable():
    """The counts > send_cap path: flag trips, on_overflow='raise' surfaces
    a structured ShuffleOverflow from shuffle and dist_join, and ample
    slack keeps the same workload clean."""
    out = run_with_devices(OVERFLOW_SNIPPET, n_devices=4)
    assert "FLAG_OK" in out and "RAISE_OK" in out
    assert "JOIN_RAISE_OK" in out and "CLEAN_OK" in out
