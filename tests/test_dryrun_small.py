"""Dry-run machinery end-to-end on a small mesh (subprocess, 8 devices):
lower+compile with explicit shardings for train/prefill/decode of reduced
archs, plus artifact schema."""
import pytest

from tests._subproc import run_with_devices

SNIPPET = r"""
import dataclasses, numpy as np, jax, jax.numpy as jnp
from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.steps import make_step
from repro.launch.dryrun import parse_collectives

mesh = jax.make_mesh((4, 2), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
parallel = ParallelConfig()

for arch in ["qwen3-8b", "falcon-mamba-7b", "qwen2-moe-a2.7b", "zamba2-7b"]:
    cfg = dataclasses.replace(reduced(get_config(arch)), d_model=64, n_heads=4,
                              n_kv_heads=2 if get_config(arch).n_kv_heads else 0)
    cfg = reduced(get_config(arch))
    for shape in [ShapeConfig("t", "train", 64, 8),
                  ShapeConfig("p", "prefill", 64, 8),
                  ShapeConfig("d", "decode", 64, 8)]:
        bundle = make_step(cfg, mesh, parallel, shape)
        with mesh:
            compiled = bundle.fn.lower(*bundle.abstract_args).compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):   # jax < 0.5 returns one dict per program
            cost = cost[0]
        assert cost.get("flops", 0) > 0, (arch, shape.kind)
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes >= 0
        colls = parse_collectives(compiled.as_text(), pod_size=4)
        print(arch, shape.kind, "OK", len(colls))
print("DRYRUN_SMALL_OK")
"""


@pytest.mark.integration
def test_dryrun_small_mesh():
    out = run_with_devices(SNIPPET, n_devices=8, timeout=900)
    assert "DRYRUN_SMALL_OK" in out
