"""Docs-honesty checks: the operator docs must cover the code that exists.

Every ``REPRO_*`` env knob referenced anywhere in ``src/`` must be
documented in docs/OPERATIONS.md, every ``BENCH_*`` mode in ``benchmarks/``
likewise, and every TraceEvent kind in the scheduler's closed
``TRACE_EVENT_KINDS`` vocabulary must appear (backticked) in
docs/ARCHITECTURE.md — plus the vocabulary itself must cover every literal
``_tr("...")`` emission, so a new kind cannot ship undeclared.

Deliberately pure-stdlib and textual (regex over source, no repro imports):
the CI lint job runs ``python tests/test_docs.py`` in an environment with
no jax installed, and pytest picks the same functions up in tier-1.
"""
import re
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = ROOT / "src"
DOCS = ROOT / "docs"


def _py_files(root):
    return [p for p in root.rglob("*.py") if "__pycache__" not in p.parts]


def _tokens(pattern, roots):
    found = set()
    for root in roots:
        for p in _py_files(root):
            found.update(re.findall(pattern, p.read_text()))
    # drop wildcard prefix mentions like "REPRO_SERVE_*" (matched up to the
    # trailing underscore) — the concrete knobs they abbreviate are matched
    # individually
    return {t for t in found if not t.endswith("_")}


def test_docs_exist():
    for name in ("ARCHITECTURE.md", "PROTOCOL.md", "OPERATIONS.md"):
        assert (DOCS / name).is_file(), f"docs/{name} is missing"
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme, \
        "README must link the architecture guide"


def test_every_env_knob_documented():
    ops = (DOCS / "OPERATIONS.md").read_text()
    knobs = _tokens(r"REPRO_[A-Z0-9_]+", [SRC])
    assert knobs, "no REPRO_ knobs found — did the source tree move?"
    missing = sorted(k for k in knobs if f"`{k}`" not in ops)
    assert not missing, \
        f"env knobs undocumented in docs/OPERATIONS.md: {missing}"


def test_every_bench_mode_documented():
    ops = (DOCS / "OPERATIONS.md").read_text()
    modes = _tokens(r"BENCH_[A-Z0-9_]+", [ROOT / "benchmarks"])
    assert modes, "no BENCH_ modes found — did benchmarks/ move?"
    missing = sorted(m for m in modes if f"`{m}`" not in ops)
    assert not missing, \
        f"bench modes undocumented in docs/OPERATIONS.md: {missing}"


def _declared_kinds():
    text = (SRC / "repro" / "core" / "scheduler.py").read_text()
    m = re.search(r"TRACE_EVENT_KINDS = frozenset\(\{(.*?)\}\)", text, re.S)
    assert m, "TRACE_EVENT_KINDS declaration not found in scheduler.py"
    return set(re.findall(r'"([a-z_]+)"', m.group(1))), text


def test_trace_kinds_closed_and_documented():
    declared, sched_text = _declared_kinds()
    # every literal emission uses a declared kind (dynamic ``_tr(ev.kind``
    # forwards only executor-event kinds, which are declared too)
    emitted = set(re.findall(r'_tr\(\s*"([a-z_]+)"', sched_text))
    undeclared = sorted(emitted - declared)
    assert not undeclared, \
        f"_tr() emits kinds missing from TRACE_EVENT_KINDS: {undeclared}"
    arch = (DOCS / "ARCHITECTURE.md").read_text()
    rows = set(re.findall(r"^\| `([a-z_]+)` \|", arch, re.M))
    missing = sorted(declared - rows)
    assert not missing, \
        f"TraceEvent kinds missing from docs/ARCHITECTURE.md table: {missing}"
    stale = sorted(rows - declared)
    assert not stale, \
        f"docs/ARCHITECTURE.md documents nonexistent kinds: {stale}"


if __name__ == "__main__":
    # standalone runner for the CI lint job (no pytest there)
    for fn in (test_docs_exist, test_every_env_knob_documented,
               test_every_bench_mode_documented,
               test_trace_kinds_closed_and_documented):
        fn()
        print(f"{fn.__name__}: OK")
