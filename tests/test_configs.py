"""Config registry: all 10 assigned archs, published param totals, shapes."""
import pytest

from repro.configs import SHAPES, get_config, list_archs, reduced, supports_shape

EXPECTED_PARAMS_B = {  # published totals (tolerance: these are arch-family sizes)
    "zamba2-7b": (6.0, 8.2),
    "falcon-mamba-7b": (6.5, 7.8),
    "internvl2-1b": (0.3, 0.7),          # LM backbone (ViT frontend is a stub)
    "llama4-maverick-400b-a17b": (380, 420),
    "qwen2-moe-a2.7b": (13, 15.5),
    "qwen3-8b": (7.5, 8.8),
    "codeqwen1.5-7b": (7.0, 8.8),
    "granite-3-8b": (7.5, 8.8),
    "minitron-8b": (8.0, 10.5),
    "whisper-medium": (0.7, 1.1),
}


def test_ten_archs_present():
    assert len(list_archs()) == 10


@pytest.mark.parametrize("arch", list_archs())
def test_param_counts_match_published(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS_B[arch]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"


def test_llama4_active_params():
    cfg = get_config("llama4-maverick-400b-a17b")
    a = cfg.active_param_count() / 1e9
    assert 15 <= a <= 19, a


def test_qwen2_moe_active_params():
    cfg = get_config("qwen2-moe-a2.7b")
    a = cfg.active_param_count() / 1e9
    assert 2.0 <= a <= 3.4, a


@pytest.mark.parametrize("arch", list_archs())
def test_reduced_configs_small(arch):
    cfg = reduced(get_config(arch))
    assert cfg.param_count() < 3e6
    assert cfg.family == get_config(arch).family


def test_exact_assigned_dims():
    q = get_config("qwen3-8b")
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab_size) == (36, 4096, 32, 8, 12288, 151936)
    assert q.qk_norm
    z = get_config("zamba2-7b")
    assert (z.n_layers, z.d_model, z.ssm_state) == (81, 3584, 64)
    f = get_config("falcon-mamba-7b")
    assert (f.n_layers, f.d_model, f.vocab_size, f.ssm_state) == (64, 4096, 65024, 16)
    m = get_config("llama4-maverick-400b-a17b")
    assert (m.n_experts, m.top_k, m.vocab_size, m.d_ff) == (128, 1, 202048, 8192)


def test_shape_table():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288


def test_long_context_applicability():
    long = SHAPES["long_500k"]
    runs = [a for a in list_archs() if supports_shape(get_config(a), long)]
    assert sorted(runs) == ["falcon-mamba-7b", "zamba2-7b"]
    # every arch supports everything else
    for a in list_archs():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(get_config(a), SHAPES[s])
