"""Optional-hypothesis guard: property-based tests skip cleanly when
``hypothesis`` is not installed, while the plain tests in the same module
keep running (a bare ``pytest.importorskip`` at module scope would skip the
whole file, losing the non-property tests)."""
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised on minimal images
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*_a, **_k):
        return lambda fn: fn

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: any strategy expression
        evaluates to None so module-level ``@given(st.xxx(...))`` decorators
        still parse."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()

__all__ = ["HAVE_HYPOTHESIS", "given", "settings", "st"]
