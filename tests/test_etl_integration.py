"""End-to-end: the dataframe ETL pipeline feeds LM training through the
runtime (the paper's 'unified data engineering + deep learning' claim),
executed on a real 4-device mesh in a subprocess."""
import pytest

from tests._subproc import run_with_devices

SNIPPET = r"""
import dataclasses, numpy as np, jax
from repro.core import build_communicator
from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh
from repro.train.data import etl_token_batches, make_events
from repro.train.trainer import Trainer

comm = build_communicator(jax.devices()[:2], axes=("df",))
cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), n_layers=2,
                          vocab_size=256)
events = make_events(4096, cfg.vocab_size, seed=0)
doc_meta = {"doc_id": np.arange(64, dtype=np.int32),
            "weight": np.ones(64, np.float32)}
batches = list(etl_token_batches(comm, events, doc_meta, batch=4, seq=32,
                                 capacity_per_rank=8192))
assert len(batches) >= 5, len(batches)
assert batches[0]["tokens"].shape == (4, 32)

mesh = make_local_mesh(2, 1)
shape = ShapeConfig("t", "train", 32, 4)
tr = Trainer(cfg, mesh, ParallelConfig(), shape)
state, losses = tr.fit(iter(batches), steps=min(len(batches), 8), log_every=0)
assert all(np.isfinite(l) for l in losses)
print("ETL_TRAIN_OK", len(batches), losses[0], losses[-1])
"""


@pytest.mark.integration
def test_etl_feeds_training():
    out = run_with_devices(SNIPPET, n_devices=4, timeout=600)
    assert "ETL_TRAIN_OK" in out
