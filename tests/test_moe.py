"""MoE invariants: capacity dispatch == dense oracle (no drops), capacity
dropping is bounded, gates renormalize, shared experts contribute."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import moe


@pytest.mark.parametrize("arch", ["qwen2-moe-a2.7b", "llama4-maverick-400b-a17b"])
def test_moe_matches_dense_oracle(arch):
    cfg = reduced(get_config(arch))  # generous capacity in reduced configs
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, 64))
    a = moe.moe_ffn(p, x, cfg)
    b = moe.moe_ffn_dense_oracle(p, x, cfg)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_dispatch_positions_stable_and_within_capacity():
    idx = jnp.asarray([[0], [1], [0], [0], [1], [0]], jnp.int32)  # top-1
    e, pos = moe.dispatch_indices(idx, n_experts=2, cap=2)
    e = np.asarray(e)
    pos = np.asarray(pos)
    # expert 0 receives tokens 0,2,3,5 -> positions 0,1,2,3 (stable)
    assert list(pos[e == 0]) == [0, 1, 2, 3]
    assert list(pos[e == 1]) == [0, 1]


def test_capacity_drop_is_graceful():
    cfg = dataclasses.replace(reduced(get_config("qwen2-moe-a2.7b")),
                              capacity_factor=0.05)
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, 64))
    out = moe.moe_ffn(p, x, cfg)   # must not crash; dropped tokens pass through 0
    assert bool(jnp.all(jnp.isfinite(out)))


def test_gates_renormalized():
    cfg = reduced(get_config("qwen2-moe-a2.7b"))
    p = moe.moe_init(jax.random.key(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (16, 64))
    _, gates = moe.route(p, x, cfg)
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, atol=1e-5)
