"""Optimizer, checkpoint (incl. elastic restore), compression, trainer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, get_config, reduced
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_local_mesh
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    p0 = rng.normal(size=(4, 3)).astype(np.float32)
    g = rng.normal(size=(4, 3)).astype(np.float32)
    cfg = opt.OptimizerConfig(peak_lr=1e-2, warmup_steps=0, total_steps=10,
                              weight_decay=0.0, clip_norm=1e9)
    params = {"w": jnp.asarray(p0)}
    state = opt.adamw_init(params)
    new_p, state, _ = opt.adamw_update({"w": jnp.asarray(g)}, state, params, cfg)
    # numpy adam step 1
    mu = 0.1 * g
    nu = 0.05 * g * g
    mu_hat = mu / (1 - 0.9)
    nu_hat = nu / (1 - 0.95)
    lr = opt.cosine_schedule(cfg, 1)
    want = p0 - float(lr) * mu_hat / (np.sqrt(nu_hat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, atol=1e-5)


def test_cosine_schedule_shape():
    cfg = opt.OptimizerConfig(peak_lr=1.0, min_lr_ratio=0.1, warmup_steps=10,
                              total_steps=110)
    assert float(opt.cosine_schedule(cfg, 0)) == 0.0
    assert float(opt.cosine_schedule(cfg, 10)) == pytest.approx(1.0, abs=1e-3)
    assert float(opt.cosine_schedule(cfg, 110)) == pytest.approx(0.1, abs=1e-3)
    assert float(opt.cosine_schedule(cfg, 60)) == pytest.approx(0.55, abs=0.01)


def test_grad_clipping():
    cfg = opt.OptimizerConfig(clip_norm=1.0, warmup_steps=0, peak_lr=1.0,
                              weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    state = opt.adamw_init(params)
    g = {"w": jnp.asarray([100.0, 0.0, 0.0])}
    _, _, metrics = opt.adamw_update(g, state, params, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(100.0)


def test_adamw_converges_quadratic():
    cfg = opt.OptimizerConfig(peak_lr=0.1, warmup_steps=0, total_steps=200,
                              weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.adamw_init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, state, _ = opt.adamw_update(g, state, params, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.1


# --------------------------------------------------------------------------
# checkpoint: roundtrip + elastic restore onto a different mesh
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.asarray([1, 2, 3], jnp.int32)}}
    h = ckpt.save(tmp_path, 7, tree, async_=True)
    h.join()
    assert ckpt.latest_step(tmp_path) == 7
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    back = ckpt.restore(tmp_path, 7, like)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_elastic_restore_different_mesh(tmp_path):
    """Save from a (1,1) mesh, restore onto a (1,1) mesh with explicit specs —
    the resharding path (device_put with NamedSharding) is exercised; on
    multi-device hosts the same code reshapes across mesh sizes."""
    from jax.sharding import PartitionSpec as P
    mesh = make_local_mesh(1, 1)
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(tmp_path, 1, tree, async_=False)
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    back = ckpt.restore(tmp_path, 1, like, mesh=mesh, specs={"w": P(None, None)})
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    assert back["w"].sharding.mesh.shape == mesh.shape


# --------------------------------------------------------------------------
# trainer end-to-end (tiny): loss decreases; checkpoint-resume continuity
# --------------------------------------------------------------------------
def test_trainer_loss_decreases_and_resumes(tmp_path):
    from repro.train.data import SyntheticCorpus
    from repro.train.trainer import Trainer

    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")), n_layers=2)
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", "train", 32, 4)
    tr = Trainer(cfg, mesh, ParallelConfig(), shape, ckpt_dir=str(tmp_path),
                 ckpt_every=10)
    corpus = SyntheticCorpus(cfg.vocab_size, 0)
    state, losses = tr.fit(corpus.batches(4, 32, 20), steps=20, log_every=0)
    assert losses[-1] < losses[0]
    assert ckpt.latest_step(tmp_path) == 20

    # resume restores step + params and continues
    tr2 = Trainer(cfg, mesh, ParallelConfig(), shape, ckpt_dir=str(tmp_path))
    st2 = tr2.maybe_restore()
    assert st2 is not None and st2.step == 20
    np.testing.assert_array_equal(np.asarray(st2.params["final_norm"]),
                                  np.asarray(state.params["final_norm"]))
    st3, losses3 = tr2.fit(corpus.batches(4, 32, 3), steps=3, state=st2,
                           log_every=0)
    assert st3.step == 23


def test_grad_accum_equivalence():
    """microbatches=2 must equal a single big batch step (same grads)."""
    from repro.distributed.steps import make_train_step
    from repro.models import get_model, make_concrete_batch, train_batch_shapes
    from repro.train.optimizer import adamw_init

    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")), n_layers=2)
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", "train", 16, 4)
    rng = np.random.default_rng(0)
    batch = make_concrete_batch(train_batch_shapes(cfg, 4, 16), rng,
                                cfg.vocab_size)
    api = get_model(cfg)
    with mesh:
        params = api.init(jax.random.key(0), cfg)
        b1 = make_train_step(cfg, mesh, ParallelConfig(microbatches=1), shape)
        b2 = make_train_step(cfg, mesh, ParallelConfig(microbatches=2), shape)
        p1, _, m1 = b1.fn(params, adamw_init(params), dict(batch))
        params2 = api.init(jax.random.key(0), cfg)
        p2, _, m2 = b2.fn(params2, adamw_init(params2), dict(batch))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-4)
    np.testing.assert_allclose(np.asarray(p1["final_norm"]),
                               np.asarray(p2["final_norm"]), atol=1e-4)
