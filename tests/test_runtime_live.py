"""LiveScheduler on real devices (subprocess, 4 host devices): private
communicators per task, heterogeneous execution of real JAX payloads, retry."""
import pytest

from tests._subproc import run_with_devices

LIVE_SNIPPET = r"""
import numpy as np, jax, jax.numpy as jnp, time
from repro.core import (HETEROGENEOUS, BATCH, PilotDescription, PilotManager,
                        RaptorMaster, TaskDescription)

pm = PilotManager()
pilot = pm.submit_pilot(PilotDescription(n_devices=4))

def payload(comm, scalar):
    # a real SPMD computation on the private mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    n = comm.size
    x = jax.device_put(np.full((n, 128), scalar, np.float32),
                       NamedSharding(comm.mesh, P("df")))
    y = jax.jit(lambda a: (a * 2).sum())(x)
    return float(y)

master = RaptorMaster(pilot, HETEROGENEOUS)
for i, r in enumerate([2, 2, 4, 1, 1]):
    master.submit(TaskDescription(name=f"t{i}", ranks=r, fn=payload,
                                  args=(float(i),), tags={"pipeline": "p"}))
rep = master.run(timeout=240)
states = [t.state.value for t in rep.tasks]
assert all(s == "DONE" for s in states), states
vals = [t.result for t in rep.tasks]
assert vals[2] == 4*128*2*2.0, vals
assert all(t.comm_build_time >= 0 for t in rep.tasks)
assert all(len(t.devices) == t.desc.ranks for t in rep.tasks)
print("LIVE_OK", rep.makespan)

# retry: payload fails twice then succeeds
attempts = {"n": 0}
def flaky(comm):
    attempts["n"] += 1
    if attempts["n"] < 3:
        raise RuntimeError("boom")
    return "ok"
m2 = RaptorMaster(pilot, HETEROGENEOUS)
m2.submit(TaskDescription(name="flaky", ranks=1, fn=flaky, max_retries=3,
                          tags={"pipeline": "p"}))
rep2 = m2.run(timeout=120)
assert rep2.tasks[0].state.value == "DONE"
assert rep2.tasks[0].retries == 2
print("RETRY_OK")
"""


@pytest.mark.integration
def test_live_scheduler_real_payloads():
    out = run_with_devices(LIVE_SNIPPET, n_devices=4)
    assert "LIVE_OK" in out and "RETRY_OK" in out


PIPELINE_SNIPPET = r"""
import numpy as np, jax
from repro.core import Pipeline, run_pipelines, PilotManager, PilotDescription

pm = PilotManager()
pilot = pm.submit_pilot(PilotDescription(n_devices=4))

def produce(comm):
    return 21

def double(comm, x):
    return x * 2

p1 = Pipeline("etl")
p1.add("produce", ranks=2, fn=produce)
p1.add("double", ranks=2, fn=double, deps=["produce"])
p2 = Pipeline("train")
p2.add("produce", ranks=2, fn=produce)
results, reports = run_pipelines([p1, p2], pilot.resource_manager)
assert results[("etl", "double")] == 42
assert results[("train", "produce")] == 21
print("PIPELINE_OK")
"""


@pytest.mark.integration
def test_mpmd_pipeline_dag():
    out = run_with_devices(PIPELINE_SNIPPET, n_devices=4)
    assert "PIPELINE_OK" in out
