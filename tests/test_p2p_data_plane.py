"""Worker-to-worker data plane: peer-to-peer collective payloads.

Wire-layer units (no subprocesses) stay in tier-1; everything spawning
worker interpreters or exercising failure injection is ``integration`` (CI
runs those — in BOTH halves of the ``REPRO_P2P`` matrix, so the hub-relay
fallback is exercised end to end, not just the happy path).
"""
import signal
import socket
import sys
import threading
import time

import pytest

from repro.core import (
    ProcessExecutor, SchedulerSession, TaskDescription, TaskState,
)
from repro.core.executors import protocol, serialize
from repro.core.executors.worker import CollectiveError, _PeerNet

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle

    # ship this module's payload functions by value: a worker process has no
    # way to import the test module
    cloudpickle.register_pickle_by_value(sys.modules[__name__])

needs_cloudpickle = pytest.mark.skipif(
    not serialize.HAVE_CLOUDPICKLE,
    reason="cloudpickle needed to ship test-local payload functions")


# ---------------------------------------------------------------------------
# wire-layer units (no subprocesses)
# ---------------------------------------------------------------------------
def test_peer_sent_sentinel_cannot_collide_with_payloads():
    """The hub placeholder must be distinguishable from every real payload:
    serialize.dumps always yields a pickle stream (b"\\x80" PROTO opcode),
    the sentinel deliberately starts with b"\\x00"."""
    for obj in (None, 0, b"", "x", [1, 2], {"a": b"\x00p2p\x00"},
                protocol.PEER_SENT):
        assert serialize.dumps(obj)[:1] == b"\x80"
    assert protocol.PEER_SENT[:1] == b"\x00"


def test_peer_net_ships_frames_between_two_nets():
    a, b = _PeerNet("wa", token="t"), _PeerNet("wb", token="t")
    a.start("127.0.0.1")
    b.start("127.0.0.1")
    blob = b"z" * (2 << 20)
    assert a.send("wb", b.data_addr, uid=1, attempt=0, seq=0, part=0,
                  payload=blob)
    assert b.take((1, 0, 0, 0), timeout=10) == blob
    # reverse direction over b's own cache, and channel reuse on a second
    # send (the cached-channel path)
    assert b.send("wa", a.data_addr, uid=1, attempt=0, seq=0, part=1,
                  payload=b"r1")
    assert b.send("wa", a.data_addr, uid=1, attempt=0, seq=1, part=1,
                  payload=b"r2")
    assert a.take((1, 0, 0, 1), timeout=10) == b"r1"
    assert a.take((1, 0, 1, 1), timeout=10) == b"r2"


def test_peer_net_rejects_wrong_token():
    srv = _PeerNet("srv", token="good")
    srv.start("127.0.0.1")
    rogue = _PeerNet("rogue", token="BAD")
    # the frame is written before the server tears the channel down, so the
    # send itself may "succeed" — the proof of rejection is that the payload
    # never reaches the mailbox
    rogue.send("srv", srv.data_addr, uid=9, attempt=0, seq=0, part=0,
               payload=b"evil")
    with pytest.raises(CollectiveError):
        srv.take((9, 0, 0, 0), timeout=0.5)


def test_peer_net_send_to_dead_port_fails_fast_not_hangs():
    sock = socket.socket()
    sock.bind(("127.0.0.1", 0))
    dead_addr = sock.getsockname()
    sock.close()                      # nothing listens here any more
    net = _PeerNet("w", token="t")
    t0 = time.monotonic()
    assert net.send("gone", dead_addr, uid=1, attempt=0, seq=0, part=0,
                    payload=b"x") is False
    assert time.monotonic() - t0 < 5.0


def test_peer_net_take_unblocked_by_abort():
    net = _PeerNet("w", token="t")
    flag = threading.Event()
    threading.Timer(0.2, flag.set).start()
    t0 = time.monotonic()
    with pytest.raises(CollectiveError, match="torn down"):
        net.take((1, 0, 0, 0), timeout=60,
                 abort=lambda: "torn down" if flag.is_set() else None)
    assert time.monotonic() - t0 < 5.0   # aborted, not timed out


def test_peer_net_purge_drops_stale_attempt_only():
    net = _PeerNet("w", token="t")
    net.put((7, 0, 0, 1), b"stale")
    net.put((7, 1, 0, 1), b"fresh")
    net.purge(7, 0)
    assert net.take((7, 1, 0, 1), timeout=1) == b"fresh"
    with pytest.raises(CollectiveError):
        net.take((7, 0, 0, 1), timeout=0.2)


def test_peer_net_frame_arriving_after_purge_is_dropped():
    """Peer and hub channels have no mutual ordering: a frame landing AFTER
    its attempt ended must be tombstoned away, not parked forever."""
    net = _PeerNet("w", token="t")
    net.purge(7, 0)                   # attempt over before the frame lands
    net.put((7, 0, 1, 1), b"late")
    assert not net._mail              # dropped, not leaked
    with pytest.raises(CollectiveError):
        net.take((7, 0, 1, 1), timeout=0.2)


# ---------------------------------------------------------------------------
# payloads shipped to workers (module-level, pickled by value)
# ---------------------------------------------------------------------------
_BLOB = 1 << 20          # well above the default 1 KiB p2p threshold


def _xfer(comm, n_coll=3, nbytes=_BLOB):
    """Each part allgathers a distinct large blob; verifies content AND
    part-ordering of the gathered list, then reports the comm counters."""
    blob = bytes([comm.part]) * nbytes
    for _ in range(n_coll):
        vals = comm.allgather(blob)
        assert len(vals) == comm.n_parts
        assert all(v == bytes([j]) * nbytes for j, v in enumerate(vals))
    comm.barrier()
    return {"p2p_bytes": comm.p2p_bytes, "hub_calls": comm.hub_calls,
            "fallbacks": comm.p2p_fallbacks, "n_parts": comm.n_parts}


def _small_gather(comm):
    vals = comm.allgather(comm.part)
    root = comm.bcast("tiny")
    return {"vals": vals, "root": root, "p2p_bytes": comm.p2p_bytes,
            "hub_calls": comm.hub_calls}


def _slow_xfer(comm, n_coll=60, nbytes=256 << 10):
    for _ in range(n_coll):
        comm.allgather(bytes([comm.part]) * nbytes)
        time.sleep(0.02)
    return {"p2p_bytes": comm.p2p_bytes, "fallbacks": comm.p2p_fallbacks}


# ---------------------------------------------------------------------------
# end-to-end (subprocess-spawning)
# ---------------------------------------------------------------------------
@needs_cloudpickle
@pytest.mark.integration
def test_large_allgather_moves_bytes_peer_to_peer():
    """Acceptance: on a 2-worker spanning task a large-payload allgather
    moves its bytes worker-to-worker — p2p_bytes > 0, zero fallbacks, and
    the hub relayed only control-sized frames (never the payloads)."""
    n_coll, nbytes = 3, _BLOB
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02, p2p=True) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="xfer", ranks=2, fn=_xfer,
                                        kwargs={"n_coll": n_coll},
                                        tags={"pipeline": "p"})], timeout=120)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        stats = task.result
        # part 0 sent each of its n_coll blobs to exactly one peer
        assert stats["n_parts"] == 2
        assert stats["p2p_bytes"] >= n_coll * nbytes
        assert stats["fallbacks"] == 0
        # executor-level evidence: both parts' bytes, and a hub that carried
        # only placeholders + the tiny barrier tokens — never a payload
        assert ex.p2p_bytes >= 2 * n_coll * nbytes
        assert ex.hub_relay_bytes < 1024
        assert ex.hub_calls == 2 * (n_coll + 1)     # control kept per coll
        # the trace carries the same evidence (p2p field on the done event)
        done = [e for e in rep.trace if e.kind == "done"]
        assert done and done[0].p2p == float(task.p2p_bytes)
        assert task.p2p_bytes == ex.p2p_bytes


@needs_cloudpickle
@pytest.mark.integration
def test_peer_port_disabled_same_workload_passes_via_hub():
    """With the peer plane off (p2p=False: workers open no data port, the
    parent ships no address book) the identical workload still passes —
    payloads relay through the hub, and the counters say so."""
    n_coll, nbytes = 3, _BLOB
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02, p2p=False) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="xfer", ranks=2, fn=_xfer,
                                        kwargs={"n_coll": n_coll},
                                        tags={"pipeline": "p"})], timeout=120)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert task.result["p2p_bytes"] == 0
        assert task.p2p_bytes == 0 and ex.p2p_bytes == 0
        assert ex.hub_relay_bytes >= 2 * n_coll * nbytes
        # same collective count either way: the data plane changes how the
        # bytes travel, never the collective semantics
        assert task.hub_calls == 2 * (n_coll + 1)


@needs_cloudpickle
@pytest.mark.integration
def test_small_payloads_stay_inline_on_hub_control_frames():
    """Control-sized payloads (ints, barrier tokens) ride the hub frame
    even with the peer plane on: a peer round-trip for 10 bytes would cost
    more than it moves."""
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02, p2p=True) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="small", ranks=2,
                                        fn=_small_gather,
                                        tags={"pipeline": "p"})], timeout=120)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert task.result["vals"] == [0, 1]
        assert task.result["root"] == "tiny"
        assert task.result["p2p_bytes"] == 0
        assert ex.p2p_bytes == 0


@needs_cloudpickle
@pytest.mark.integration
def test_three_worker_allgather_is_part_ordered():
    """3 parts on 3 workers: every part receives every other part's large
    payload directly, and the gathered list stays part-index ordered."""
    with ProcessExecutor(n_workers=3, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02, p2p=True) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="tri", ranks=3, fn=_xfer,
                                        kwargs={"n_coll": 2},
                                        tags={"pipeline": "p"})], timeout=120)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert task.result["n_parts"] == 3
        assert task.result["fallbacks"] == 0
        # each of 3 parts sent 2 blobs to 2 peers
        assert ex.p2p_bytes >= 3 * 2 * 2 * _BLOB


@needs_cloudpickle
@pytest.mark.integration
def test_sigkill_mid_peer_transfer_recovers_via_retry_with_exclusion():
    """Acceptance + failure semantics: SIGKILL a worker while a spanning
    task is streaming large payloads peer-to-peer.  The loss must surface as
    the existing targeted ``device_failure`` (exact inventory) and the task
    must retry WITH EXCLUSION on the survivors — completing over fresh peer
    channels (attempt-keyed mailbox: no stale frame of the dead attempt is
    ever credited to the retry) — not hang out the collective timeout."""
    with ProcessExecutor(n_workers=3, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02, p2p=True) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="victim", ranks=2, fn=_slow_xfer,
                                     max_retries=2, tags={"pipeline": "p"})])
        time.sleep(0.5)               # mid-transfer: several colls in flight
        ex.kill_worker("w0", signal.SIGKILL)
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        fails = rep.events("device_failure")
        assert len(fails) == 1 and fails[0].value == 1.0
        assert task.retries >= 1 and len(rep.events("retry")) >= 1
        # exclusion recorded the dead worker's device; the retry ran on the
        # two survivors and still used the peer plane (no stale channels)
        assert any(d.worker == "w0" for d in task.excluded_devices)
        assert {d.worker for d in task.devices} == {"w1", "w2"}
        assert task.result["p2p_bytes"] > 0
        assert task.result["fallbacks"] == 0
        assert rm.total == 2          # pool shrank by exactly the dead node


@needs_cloudpickle
@pytest.mark.integration
def test_env_var_matrix_knob_disables_peer_plane(monkeypatch):
    """REPRO_P2P=0 (the CI matrix knob) must force hub relay without any
    code change — the default-resolution path of ProcessExecutor(p2p=None)."""
    monkeypatch.setenv("REPRO_P2P", "0")
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        assert ex.p2p is False
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="xfer", ranks=2, fn=_xfer,
                                        kwargs={"n_coll": 1},
                                        tags={"pipeline": "p"})], timeout=120)
        assert rep.tasks[0].state == TaskState.DONE
        assert ex.p2p_bytes == 0 and ex.hub_relay_bytes >= 2 * _BLOB
