"""Run a python snippet in a fresh interpreter with N host devices.
Used by integration tests that need a real multi-device mesh."""
import os
import subprocess
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_with_devices(snippet: str, n_devices: int = 4, timeout: int = 480) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise AssertionError(f"subprocess failed:\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}")
    return r.stdout
