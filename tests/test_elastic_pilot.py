"""Elastic pilot: runtime ``add_worker``/``retire_worker`` on
ProcessExecutor, plus the sim-side ``grow_at``/``retire_at`` injections.

Fast virtual-clock scenarios stay in tier-1; everything that spawns worker
interpreters is marked ``integration`` and runs in the CI proc-executor
matrix under BOTH halves of ``REPRO_P2P`` (the spanning tests assert the
peer-plane evidence only when the plane is on).
"""
import signal
import sys
import time

import pytest

from repro.core import (
    ProcDevice, ProcessExecutor, ResourceManager, SchedulerSession,
    SimOptions, TaskDescription, TaskState, VirtualClockExecutor, simulate,
)
from repro.core.executors import serialize
from repro.core.executors.worker import _PeerNet

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle

    # ship this module's payload functions by value: a worker process has no
    # way to import the test module
    cloudpickle.register_pickle_by_value(sys.modules[__name__])

needs_cloudpickle = pytest.mark.skipif(
    not serialize.HAVE_CLOUDPICKLE,
    reason="cloudpickle needed to ship test-local payload functions")


# ---------------------------------------------------------------------------
# virtual clock: deterministic elastic scenarios (tier-1)
# ---------------------------------------------------------------------------
def test_sim_grow_at_unblocks_pending_deterministically():
    """A task wider than the initial inventory dispatches at exactly the
    grow instant — the sim analogue of add_worker, so elastic scenarios
    replay deterministically at paper scale."""
    rep = simulate(
        [TaskDescription(name="wide", ranks=4, fn=None,
                         duration_model=lambda r: 2.0,
                         tags={"pipeline": "p"})],
        2, SimOptions(noise=0.0, overhead_model=lambda r: 0.0,
                      grow_at=[(1.0, 2)]))
    task = rep.tasks[0]
    assert task.state == TaskState.DONE
    grow = rep.events("grow")
    assert len(grow) == 1 and grow[0].value == 2.0
    disp = rep.events("dispatch")[0]
    assert disp.t == pytest.approx(1.0)      # same step as the grow
    assert rep.makespan == pytest.approx(3.0)


def test_sim_grow_invents_fresh_int_handles_on_stable_topology():
    """Anonymous grow on an all-int pool extends the integer range, so the
    synthetic ``devices_per_node`` topology classifies the new devices as
    new nodes rather than aliasing existing ones."""
    ex = VirtualClockExecutor(SimOptions(noise=0.0,
                                         overhead_model=lambda r: 0.0,
                                         devices_per_node=2,
                                         grow_at=[(1.0, 2)]))
    rm = ResourceManager([0, 1])
    sess = SchedulerSession(ex, rm)
    rep = sess.run([TaskDescription(name="wide", ranks=4, fn=None,
                                    duration_model=lambda r: 1.0,
                                    tags={"pipeline": "p"})])
    assert rep.tasks[0].state == TaskState.DONE
    assert sorted(rm.all_devices) == [0, 1, 2, 3]
    assert ex.topology(rm.all_devices).n_nodes == 2


def test_sim_retire_at_withdraws_free_devices_without_failure():
    rep = simulate(
        [TaskDescription(name=f"t{i}", ranks=1, fn=None,
                         duration_model=lambda r: 5.0,
                         tags={"pipeline": "p"}) for i in range(2)],
        4, SimOptions(noise=0.0, overhead_model=lambda r: 0.0,
                      retire_at=[(1.0, 2)]))
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    ret = rep.events("retire")
    assert len(ret) == 1 and ret[0].value == 2.0
    assert not rep.events("device_failure") and not rep.events("fail")


def test_sim_grow_then_retire_round_trip_inventory():
    """Grow and retire are inverses on the pool: total returns to the seed
    count and the trace carries one event of each kind."""
    rm = ResourceManager([0, 1])
    sess = SchedulerSession(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0,
                                        grow_at=[(1.0, 2)],
                                        retire_at=[(3.0, 2)])),
        rm)
    rep = sess.run([TaskDescription(name=f"t{i}", ranks=1, fn=None,
                                    duration_model=lambda r: 5.0,
                                    tags={"pipeline": "p"})
                    for i in range(2)])
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    assert len(rep.events("grow")) == len(rep.events("retire")) == 1
    assert rm.total == 2


def test_regrown_retired_handle_returns_to_full_service():
    """Re-adding a previously retired/failed handle (the node came back) is
    a rehabilitation: it must leave the failed set, lease normally, AND be
    releasable — a handle stuck in ``_failed`` would be silently dropped by
    release() after its first lease, a permanent one-device pool leak."""
    rm = ResourceManager(["d0", "d1"])
    rm.fail_devices(["d1"])               # the retire/device_failure path
    assert rm.total == 1
    rm.add_devices(["d1"])                # elastic re-grow of the same id
    assert rm.total == 2 and "d1" not in rm.failed_devices
    got = rm.allocate(2)
    rm.release(got)
    assert rm.n_free == 2                 # the re-grown device came back
    # idempotence: replaying the grow adds nothing
    rm.add_devices(["d1", "d0"])
    assert rm.total == 2


# ---------------------------------------------------------------------------
# wire-layer unit: peer-channel eviction (no subprocesses)
# ---------------------------------------------------------------------------
def test_peer_net_evict_closes_cached_channel_and_reconnects():
    a, b = _PeerNet("wa", token="t"), _PeerNet("wb", token="t")
    a.start("127.0.0.1")
    b.start("127.0.0.1")
    assert a.send("wb", b.data_addr, uid=1, attempt=0, seq=0, part=0,
                  payload=b"one")
    assert "wb" in a._out                 # channel cached
    a.evict("wb")
    assert "wb" not in a._out             # evicted AND closed
    # a later legitimate send (e.g. the id belongs to a live peer again in
    # a fresh address book) reconnects instead of reusing the dead socket
    assert a.send("wb", b.data_addr, uid=1, attempt=0, seq=1, part=0,
                  payload=b"two")
    assert b.take((1, 0, 0, 0), timeout=10) == b"one"
    assert b.take((1, 0, 1, 0), timeout=10) == b"two"
    # evicting an unknown id is a no-op, not an error
    a.evict("stranger")


# ---------------------------------------------------------------------------
# payloads shipped to workers (module-level, pickled by value)
# ---------------------------------------------------------------------------
_BLOB = 1 << 20     # above the 1 KiB p2p threshold


def _devs(comm):
    return tuple(map(str, comm.devices))


def _span_xfer(comm, nbytes=_BLOB):
    """One large allgather across all parts; returns comm evidence."""
    vals = comm.allgather(bytes([comm.part]) * nbytes)
    assert all(v == bytes([j]) * nbytes for j, v in enumerate(vals))
    return {"n_parts": comm.n_parts, "p2p_bytes": comm.p2p_bytes,
            "hub_calls": comm.hub_calls, "fallbacks": comm.p2p_fallbacks,
            "devices": tuple(map(str, comm.devices))}


def _slow_span(comm, dur=0.5):
    time.sleep(dur)
    parts = comm.allgather(comm.part)
    return {"parts": parts, "devices": tuple(map(str, comm.devices)),
            "fallbacks": comm.p2p_fallbacks}


def _sleepy(comm, dur=0.3):
    time.sleep(dur)
    return str(comm.devices[0])


# ---------------------------------------------------------------------------
# end-to-end (subprocess-spawning)
# ---------------------------------------------------------------------------
@needs_cloudpickle
@pytest.mark.integration
def test_add_worker_unblocks_pending_within_one_step():
    """Acceptance: a task wider than the initial inventory sits pending; it
    dispatches within one scheduler step of ``add_worker`` returning, with
    a ``grow`` trace event naming the new inventory — matching the sim's
    ``grow_at`` skeleton exactly."""
    with ProcessExecutor(n_workers=1, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="wide", ranks=2, fn=_devs,
                                     tags={"pipeline": "p"})])
        assert not sess.running           # cannot fit 1 device
        wid = ex.add_worker(devices_per_worker=1)
        assert wid == "w1"
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        # exact skeleton: no scheduling action between grow and the dispatch
        # (periodic telemetry heartbeats are passive and may interleave)
        assert [(e.kind, e.task) for e in rep.trace
                if e.kind != "telemetry"] == \
            [("submit", "wide"), ("grow", ""), ("dispatch", "wide"),
             ("done", "wide")]
        assert next(e.value for e in rep.events("grow")) == 1.0
        # inventory registered into the LIVE ResourceManager...
        assert rm.total == 2 and ProcDevice("w1", 0) in rm
        # ...and the placement layer sees the new node immediately
        assert ex.topology(ex.devices()).n_nodes == 2
        # sim equivalence: same skeleton under grow_at
        rep_sim = simulate(
            [TaskDescription(name="wide", ranks=2, fn=None,
                             duration_model=lambda r: 1.0,
                             tags={"pipeline": "p"})],
            1, SimOptions(noise=0.0, overhead_model=lambda r: 0.0,
                          grow_at=[(1.0, 1)]))
        assert [(e.kind, e.task) for e in rep_sim.trace] == \
            [(e.kind, e.task) for e in rep.trace
             if e.kind != "telemetry"]


@needs_cloudpickle
@pytest.mark.integration
def test_spanning_task_across_old_and_new_worker_moves_bytes_p2p():
    """A task spanning the original worker AND a runtime-added one
    completes its large allgather; with the peer plane on, the bytes move
    worker-to-worker (the newcomer's data port entered the address book via
    its HELLO), with it off, the hub relays them — either way, no
    fallbacks."""
    with ProcessExecutor(n_workers=1, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        sess.submit([TaskDescription(name="span", ranks=2, fn=_span_xfer,
                                     tags={"pipeline": "p"})])
        ex.add_worker(devices_per_worker=1)
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        stats = task.result
        assert stats["n_parts"] == 2
        assert {d.split(":")[0] for d in stats["devices"]} <= {"w0", "w1"}
        assert {d.worker for d in task.devices} == {"w0", "w1"}
        assert stats["fallbacks"] == 0
        if ex.p2p:
            assert stats["p2p_bytes"] >= _BLOB     # to/from the newcomer
            assert ex.p2p_bytes >= 2 * _BLOB
        else:
            assert stats["p2p_bytes"] == 0
            assert ex.hub_relay_bytes >= 2 * _BLOB


@needs_cloudpickle
@pytest.mark.integration
def test_retire_worker_drains_without_losing_results():
    """Graceful retire while a spanning part runs on the retiree: the task
    completes with its result intact (drain), the inventory leaves the pool
    as a ``retire`` trace event — never a device_failure, never a retry —
    and follow-up work runs on the survivor."""
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="span", ranks=2, fn=_slow_span,
                                     tags={"pipeline": "p"})])
        t0 = time.monotonic()
        ex.retire_worker("w1")            # blocks until the part drained
        assert time.monotonic() - t0 >= 0.3
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert task.result["parts"] == [0, 1]     # nothing lost
        assert task.retries == 0
        ret = rep.events("retire")
        assert len(ret) == 1 and ret[0].value == 1.0   # the BUSY device left
        # the pool too: a drain stops leasing immediately, it does not wait
        assert not rep.events("device_failure") and not rep.events("fail")
        assert rm.total == 1              # only the survivor remains
        # the pool keeps working: a follow-up lands on w0
        rep2 = sess_run_one(ex, rm)
        assert rep2.startswith("w0")


def sess_run_one(ex, rm):
    sess = SchedulerSession(ex, rm, tick=0.02)
    rep = sess.run([TaskDescription(name="after", ranks=1, fn=_devs,
                                    tags={"pipeline": "p"})], timeout=60)
    assert rep.tasks[0].state == TaskState.DONE
    return rep.tasks[0].result[0]


@needs_cloudpickle
@pytest.mark.integration
def test_immediate_retire_retries_spanning_task_on_survivors():
    """``immediate=True``: the retiree's in-flight part is failed on the
    spot; the task retries WITH EXCLUSION on the surviving workers (the
    retired inventory already left the pool) and completes — zero stale
    peer frames absorbed (attempt-keyed mailboxes)."""
    with ProcessExecutor(n_workers=3, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="span", ranks=2, fn=_slow_span,
                                     kwargs={"dur": 1.0}, max_retries=2,
                                     tags={"pipeline": "p"})])
        # spread placed the task on w0+w1; retire w1 under it, immediately
        ex.retire_worker("w1", immediate=True)
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert task.retries >= 1 and len(rep.events("retry")) >= 1
        assert {d.worker for d in task.devices} == {"w0", "w2"}
        assert task.result["parts"] == [0, 1]
        assert task.result["fallbacks"] == 0
        assert rep.events("retire") and not rep.events("device_failure")
        assert rm.total == 2


@needs_cloudpickle
@pytest.mark.integration
def test_clean_retire_keeps_peer_plane_fallback_free():
    """After a spanning task warmed peer channels to w2, a clean retire of
    w2 must leave the remaining workers' peer plane healthy: the next
    spanning task (w0+w1) completes with ``p2p_fallbacks == 0`` — the
    PEERS_UPDATE eviction, not a per-payload failure, removed the retiree."""
    with ProcessExecutor(n_workers=3, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        rep = sess.run([TaskDescription(name="warm", ranks=3, fn=_span_xfer,
                                        tags={"pipeline": "p"})], timeout=120)
        assert rep.tasks[0].state == TaskState.DONE
        assert rep.tasks[0].result["fallbacks"] == 0
        ex.retire_worker("w2")
        sess2 = SchedulerSession(ex, rm, tick=0.02)
        rep2 = sess2.run([TaskDescription(name="after", ranks=2,
                                          fn=_span_xfer,
                                          tags={"pipeline": "p"})],
                         timeout=120)
        task = rep2.tasks[0]
        assert task.state == TaskState.DONE
        assert task.result["n_parts"] == 2
        assert {d.worker for d in task.devices} == {"w0", "w1"}
        assert task.result["fallbacks"] == 0
        if ex.p2p:
            assert task.result["p2p_bytes"] >= _BLOB


@needs_cloudpickle
@pytest.mark.integration
def test_sigkill_of_just_added_worker_is_targeted_failure():
    """A runtime-added worker is a first-class liveness citizen: SIGKILLing
    it yields the usual TARGETED device_failure (its exact inventory) and
    the victim task retries with exclusion on the original worker."""
    with ProcessExecutor(n_workers=1, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        # hold w0 so the victim must land on the newcomer — long enough to
        # outlive the interpreter-spawn cost of add_worker below
        sess.submit([TaskDescription(name="hold", ranks=1, fn=_sleepy,
                                     kwargs={"dur": 8.0},
                                     tags={"pipeline": "p"})])
        wid = ex.add_worker(devices_per_worker=1)
        sess.submit([TaskDescription(name="victim", ranks=1, fn=_sleepy,
                                     kwargs={"dur": 5.0}, max_retries=2,
                                     tags={"pipeline": "p"})])
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            sess.wait_any(timeout=0.1)
            victim = next((t for t in sess.running.values()
                           if t.desc.name == "victim"), None)
            if victim is not None:
                assert {d.worker for d in victim.devices} == {wid}
                break
        else:
            pytest.fail("victim never dispatched onto the added worker")
        ex.kill_worker(wid, signal.SIGKILL)
        # shorten the second attempt so the drain stays quick
        victim.desc.kwargs = {"dur": 0.1}
        rep = sess.drain(timeout=120).close()
        by = {t.desc.name: t for t in rep.tasks}
        assert by["victim"].state == TaskState.DONE
        fails = rep.events("device_failure")
        assert len(fails) == 1 and fails[0].value == 1.0
        assert by["victim"].retries >= 1
        assert ProcDevice(wid, 0) in by["victim"].excluded_devices
        assert by["victim"].result.startswith("w0")
        assert rm.total == 1


@needs_cloudpickle
@pytest.mark.integration
def test_grow_trace_equivalence_sim_vs_process():
    """The grow lifecycle produces the identical ordered skeleton on the
    virtual clock and the multi-process pilot — the elastic path lives in
    the core, the executors only deliver the event."""
    kinds = ("submit", "dispatch", "grow", "done")
    sim = SchedulerSession(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0,
                                        grow_at=[(2.0, 1)])),
        ResourceManager([0]))
    rep_sim = sim.run([TaskDescription(name="a", ranks=1, fn=None,
                                       duration_model=lambda r: 1.0,
                                       tags={"pipeline": "p"}),
                       TaskDescription(name="wide", ranks=2, fn=None,
                                       duration_model=lambda r: 1.0,
                                       tags={"pipeline": "p"})])

    with ProcessExecutor(n_workers=1, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        sess.submit([TaskDescription(name="a", ranks=1, fn=_sleepy,
                                     kwargs={"dur": 0.1},
                                     tags={"pipeline": "p"}),
                     TaskDescription(name="wide", ranks=2, fn=_devs,
                                     tags={"pipeline": "p"})])
        got = sess.wait_any(timeout=60)       # a done; wide still infeasible
        assert [t.desc.name for t in got] == ["a"]
        ex.add_worker(devices_per_worker=1)
        rep_proc = sess.drain(timeout=120).close()

    def skel(rep):
        return [(e.kind, e.task) for e in rep.trace if e.kind in kinds]

    assert all(t.state == TaskState.DONE for t in rep_proc.tasks)
    assert skel(rep_sim) == skel(rep_proc)
