"""Per-arch REDUCED-config smoke tests (assignment requirement): one forward
and one train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ParallelConfig, get_config, list_archs, reduced
from repro.configs.base import ShapeConfig
from repro.distributed.steps import make_train_step
from repro.launch.mesh import make_local_mesh
from repro.models import get_model, make_concrete_batch, train_batch_shapes

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = reduced(get_config(arch))
    api = get_model(cfg)
    params = api.init(jax.random.key(0), cfg)
    B, S = 2, 16
    batch = make_concrete_batch(train_batch_shapes(cfg, B, S), RNG, cfg.vocab_size)
    logits = api.forward(params, cfg, batch)
    extra = cfg.n_patches if cfg.family == "vlm" else 0
    assert logits.shape == (B, S + extra, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", list_archs())
def test_one_train_step(arch):
    cfg = reduced(get_config(arch))
    mesh = make_local_mesh(1, 1)
    shape = ShapeConfig("t", "train", 16, 2)
    bundle = make_train_step(cfg, mesh, ParallelConfig(), shape)
    api = get_model(cfg)
    with mesh:
        params = api.init(jax.random.key(0), cfg)
        before = np.asarray(params["final_norm"]).copy()
        from repro.train.optimizer import adamw_init
        opt = adamw_init(params)
        batch = make_concrete_batch(train_batch_shapes(cfg, 2, 16), RNG,
                                    cfg.vocab_size)
        p2, o2, metrics = bundle.fn(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(o2["count"]) == 1
    assert np.any(np.asarray(p2["final_norm"]) != before)  # params updated
