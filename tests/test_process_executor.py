"""ProcessExecutor: the multi-process pilot runtime.

Fast protocol/serialization units and a 2-worker smoke run stay in tier-1;
everything that spawns several fresh interpreters or exercises failure
injection is marked ``integration`` (CI runs those in the dedicated
process-executor job under --xla_force_host_platform_device_count).
"""
import signal
import socket
import sys
import threading
import time

import pytest

from repro.core import (
    ProcDevice, ProcessExecutor, ResourceManager, SchedulerSession,
    TaskDescription, TaskState,
)
from repro.core.executors import serialize
from repro.core.executors.protocol import Channel, ConnectionClosed

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle

    # ship this module's payload functions by value: a worker process has no
    # way to import the test module
    cloudpickle.register_pickle_by_value(sys.modules[__name__])

needs_cloudpickle = pytest.mark.skipif(
    not serialize.HAVE_CLOUDPICKLE,
    reason="cloudpickle needed to ship test-local payload functions")


# ---------------------------------------------------------------------------
# wire-layer units (no subprocesses)
# ---------------------------------------------------------------------------
def test_channel_roundtrip_and_eof():
    a, b = socket.socketpair()
    ca, cb = Channel(a), Channel(b)
    big = b"x" * (3 << 20)
    # a frame larger than the socket buffer: send from a thread so the
    # reader drains concurrently (as the real duplex channel does)
    sender = threading.Thread(target=ca.send, args=("launch",),
                              kwargs={"uid": 7, "payload": big})
    sender.start()
    kind, d = cb.recv()
    sender.join()
    assert kind == "launch" and d["uid"] == 7 and d["payload"] == big
    cb.send("part_done", uid=7, part=0)
    assert ca.recv()[0] == "part_done"
    cb.close()
    with pytest.raises(ConnectionClosed):
        ca.recv()


def test_channel_send_is_thread_safe():
    a, b = socket.socketpair()
    ca, cb = Channel(a), Channel(b)
    n_threads, n_frames = 4, 50
    payload = b"y" * 10_000

    def sender(tid):
        for i in range(n_frames):
            ca.send("coll", tid=tid, i=i, payload=payload)

    threads = [threading.Thread(target=sender, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    got = [cb.recv() for _ in range(n_threads * n_frames)]
    for t in threads:
        t.join()
    # interleaved multi-threaded sends must never corrupt framing
    assert all(kind == "coll" and d["payload"] == payload for kind, d in got)


def test_serialize_roundtrip():
    fn, args, kwargs = (sorted, ([3, 1, 2],), {"reverse": True})
    f2, a2, k2 = serialize.loads(serialize.dumps((fn, args, kwargs)))
    assert f2(*a2, **k2) == [3, 2, 1]
    if serialize.HAVE_CLOUDPICKLE:
        add = serialize.loads(serialize.dumps(lambda x: x + 1))
        assert add(41) == 42


def test_serialize_without_cloudpickle_rejects_main_payloads(monkeypatch):
    """Stdlib pickle dumps a __main__ function BY REFERENCE (succeeds), then
    explodes opaquely inside the worker whose __main__ differs — must be
    rejected at dump time with an actionable error instead."""
    monkeypatch.setattr(serialize, "HAVE_CLOUDPICKLE", False)

    def fake_main_fn():
        return 1

    fake_main_fn.__module__ = "__main__"
    with pytest.raises(TypeError, match="cloudpickle"):
        serialize.dumps((fake_main_fn, (), {}))
    # importable module-level callables still pass through
    assert serialize.loads(serialize.dumps((sorted, ([2, 1],), {})))


def test_proc_device_is_stable_rm_handle():
    devs = [ProcDevice("w0", 0), ProcDevice("w0", 1), ProcDevice("w1", 0)]
    rm = ResourceManager(devs)
    got = rm.allocate(2)
    assert got == (devs[0], devs[1])
    rm.release(got)
    rm.fail_devices([devs[2]])
    assert rm.total == 2 and devs[2] not in rm


# ---------------------------------------------------------------------------
# payloads shipped to workers (module-level, pickled by value)
# ---------------------------------------------------------------------------
def _echo(comm, tag="t"):
    return (tag, comm.size, comm.local_size, tuple(map(str, comm.devices)))


def _span_gather(comm):
    parts = comm.allgather(comm.global_ranks)
    root = comm.bcast(("from-part0", comm.rank))
    comm.barrier()
    return {"parts": parts, "root": root, "world": comm.size}


def _sleepy(comm, dur=0.8):
    time.sleep(dur)
    return str(comm.devices[0])


def _flaky_on_w0(comm):
    dev = str(comm.devices[0])
    if dev.startswith("w0"):
        raise RuntimeError(f"bad device {dev}")
    return dev


# ---------------------------------------------------------------------------
# end-to-end (subprocess-spawning)
# ---------------------------------------------------------------------------
@needs_cloudpickle
def test_process_executor_smoke_spanning_task():
    """2 workers x 2 devices: single-worker tasks plus one 4-rank task whose
    ranks span both worker processes and allgather/bcast through the hub."""
    with ProcessExecutor(n_workers=2, devices_per_worker=2,
                         build_comm=False, heartbeat_interval=0.2) as ex:
        assert ex.devices() == tuple(
            ProcDevice(f"w{w}", i) for w in range(2) for i in range(2))
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        # b first so its 2 ranks land on w0's 2 devices (allocation is
        # first-free in submission order); a then takes a w1 device
        rep = sess.run(
            [TaskDescription(name="b", ranks=2, fn=_echo, kwargs={"tag": "b"},
                             tags={"pipeline": "p"}),
             TaskDescription(name="a", ranks=1, fn=_echo, kwargs={"tag": "a"},
                             tags={"pipeline": "p"}),
             TaskDescription(name="span", ranks=4, fn=_span_gather,
                             tags={"pipeline": "p"})],
            timeout=120)
        by = {t.desc.name: t for t in rep.tasks}
        assert all(t.state == TaskState.DONE for t in rep.tasks)
        assert by["a"].result[1:3] == (1, 1)
        assert by["b"].result[1:3] == (2, 2)   # one worker owns both ranks
        span = by["span"].result
        assert span["world"] == 4
        assert len(span["parts"]) == 2              # one part per worker
        assert sorted(r for p in span["parts"] for r in p) == [0, 1, 2, 3]
        assert span["root"][0] == "from-part0"
        # same TraceEvent schema as every other executor
        assert [e.kind for e in rep.trace if e.task == "span"] == \
            ["submit", "dispatch", "done"]


@needs_cloudpickle
@pytest.mark.integration
def test_worker_sigkill_fails_devices_and_retries_on_survivors():
    """SIGKILL one worker mid-run: its inventory dies (device_failure trace
    naming the lost count), in-flight tasks fail and retry with device
    exclusion on the surviving worker — true process isolation, not an
    injected failure."""
    with ProcessExecutor(n_workers=2, devices_per_worker=2,
                         build_comm=False, heartbeat_interval=0.2) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        descs = [TaskDescription(name=f"t{i}", ranks=1, fn=_sleepy,
                                 max_retries=2, tags={"pipeline": "p"})
                 for i in range(6)]
        sess.submit(descs)
        time.sleep(0.3)               # 4 tasks are now running, 2 pending
        ex.kill_worker("w0", signal.SIGKILL)
        rep = sess.drain(timeout=120).close()
        assert all(t.state == TaskState.DONE for t in rep.tasks)
        fails = rep.events("device_failure")
        assert len(fails) == 1 and fails[0].value == 2.0
        assert len(rep.events("retry")) >= 1
        assert rm.total == 2          # pool shrank to the surviving worker
        retried = [t for t in rep.tasks if t.retries]
        assert retried and all(
            d.worker == "w0" for t in retried for d in t.excluded_devices)
        assert all(t.result.startswith("w1") for t in retried)


@needs_cloudpickle
@pytest.mark.integration
def test_hung_worker_detected_by_heartbeat_timeout():
    """SIGSTOP (hang, not crash): no EOF arrives, so only the heartbeat
    monitor can notice; it must kill the worker and fail its devices."""
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, heartbeat_interval=0.15,
                         heartbeat_timeout=0.8) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name=f"t{i}", ranks=1, fn=_sleepy,
                                     args=(0.5,), max_retries=2,
                                     tags={"pipeline": "p"})
                     for i in range(3)])
        time.sleep(0.2)
        ex.workers["w0"].proc.send_signal(signal.SIGSTOP)
        rep = sess.drain(timeout=120).close()
        assert all(t.state == TaskState.DONE for t in rep.tasks)
        assert len(rep.events("device_failure")) == 1
        assert rm.total == 1


@needs_cloudpickle
@pytest.mark.integration
def test_retry_with_exclusion_on_payload_error_via_livescheduler():
    """A payload that only fails on w0 devices: the retry must prefer the
    other worker's devices (same exclusion logic as the thread executor).
    Driven through LiveScheduler to cover the selectable-backend wiring."""
    from repro.core import LiveScheduler
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, heartbeat_interval=0.2) as ex:
        sched = LiveScheduler(ex.resource_manager(), executor=ex)
        rep = sched.run([TaskDescription(name="f", ranks=1, fn=_flaky_on_w0,
                                         max_retries=2,
                                         tags={"pipeline": "p"})],
                        timeout=120)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert task.result.startswith("w1")
        assert ProcDevice("w0", 0) in task.excluded_devices
        assert rep.n_retries == 1


def _fail_part0_attempt0(comm):
    if comm.part == 0 and comm.attempt == 0:
        raise RuntimeError("first attempt dies")
    if comm.part == 1:
        time.sleep(0.5)      # outlive the retry's launch: the stale PART_DONE
        # of attempt 0 arrives while attempt 1 is in flight
    return f"ok-attempt{comm.attempt}"


@needs_cloudpickle
@pytest.mark.integration
def test_stale_part_of_failed_attempt_not_credited_to_retry():
    """The scheduler reuses task.uid across retries.  A slow sibling part of
    a FAILED attempt must not be credited to the retry of the same task —
    frames are matched on (uid, attempt), so the retry completes with its
    own results only."""
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, heartbeat_interval=0.2) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="t", ranks=2,
                                        fn=_fail_part0_attempt0,
                                        max_retries=2,
                                        tags={"pipeline": "p"})], timeout=120)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE
        assert rep.n_retries == 1
        assert task.result == "ok-attempt1"   # never attempt 0's payload


def _span_part0_dies(comm):
    if comm.part == 0:
        raise RuntimeError("part0 dies")
    time.sleep(0.8)
    return "survivor"


def _quick(comm):
    return "quick"


@needs_cloudpickle
@pytest.mark.integration
def test_partial_failure_holds_devices_until_sibling_part_finishes():
    """One part of a spanning task fails fast while the sibling still
    computes: the task's devices must NOT be released (and re-issued to a
    pending task) until the surviving part actually finishes — otherwise
    two payloads run on one worker device."""
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, heartbeat_interval=0.2) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run(
            [TaskDescription(name="span", ranks=2, fn=_span_part0_dies,
                             max_retries=0, tags={"pipeline": "p"}),
             TaskDescription(name="waiter", ranks=1, fn=_quick,
                             tags={"pipeline": "p"})],
            timeout=120)
        by = {t.desc.name: t for t in rep.tasks}
        assert by["span"].state == TaskState.FAILED
        assert by["waiter"].state == TaskState.DONE
        t_disp = {e.task: e.t for e in rep.trace if e.kind == "dispatch"}
        t_fail = next(e.t for e in rep.trace if e.kind == "fail")
        # the fail surfaces only after the 0.8s surviving part drained ...
        assert t_fail - t_disp["span"] >= 0.7
        # ... and only then is the freed device re-issued
        assert t_disp["waiter"] >= t_fail - 0.05


def _hold(comm, dur=0.8):
    time.sleep(dur)
    return "held"


def _placement_probe(comm, n_coll=4):
    for _ in range(n_coll):
        comm.allgather(comm.local_size)
    comm.bcast("x")
    comm.barrier()
    return {"n_parts": comm.n_parts, "hub_calls": comm.hub_calls,
            "local_size": comm.local_size, "placement": comm.placement,
            "devices": tuple(map(str, comm.devices))}


@needs_cloudpickle
@pytest.mark.integration
def test_pack_places_fitting_task_on_one_worker():
    """Acceptance: with w0 fragmented by a 1-rank blocker, a 2-rank task
    under ``pack`` is placed on exactly ONE worker — a single part whose
    collectives complete locally (zero hub round-trips) — while ``spread``
    reproduces today's flat order and straddles both workers, paying the
    parent hub for every collective."""
    results = {}
    for placement in ("spread", "pack"):
        with ProcessExecutor(n_workers=2, devices_per_worker=2,
                             build_comm=False, heartbeat_interval=0.2,
                             tick=0.02) as ex:
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02,
                                    placement=placement)
            rep = sess.run(
                [TaskDescription(name="hold", ranks=1, fn=_hold,
                                 tags={"pipeline": "p"}),
                 TaskDescription(name="probe", ranks=2, fn=_placement_probe,
                                 tags={"pipeline": "p"})], timeout=120)
            assert all(t.state == TaskState.DONE for t in rep.tasks)
            by = {t.desc.name: t for t in rep.tasks}
            results[placement] = by["probe"].result
    spread, pack = results["spread"], results["pack"]
    # spread = the historical behaviour: the task spans workers and every
    # collective (4 allgathers + bcast + barrier) is a hub round-trip
    assert spread["n_parts"] == 2 and spread["local_size"] == 1
    assert spread["hub_calls"] == 6
    # pack: the worker-part spec is a single part on a single worker, and
    # the SAME payload never touches the hub
    assert pack["n_parts"] == 1 and pack["local_size"] == 2
    assert pack["hub_calls"] == 0
    assert pack["placement"] == "pack"
    assert len({d.split(":")[0] for d in pack["devices"]}) == 1


def _psum_local(comm):
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    n = comm.local_size
    f = jax.jit(jax.shard_map(lambda x: jax.lax.psum(x, "df"),
                              mesh=comm.mesh, in_specs=P("df"),
                              out_specs=P()))
    return float(f(jnp.ones((n, 2))).sum())


def _psum_global(comm):
    # local psum over this worker's private sub-mesh, then a cross-process
    # reduction through the hub — the heterogeneous communicator spanning
    # nodes that the paper builds with MPI groups
    return sum(comm.allgather(_psum_local(comm)))


@needs_cloudpickle
@pytest.mark.integration
def test_real_jax_mesh_per_worker_and_cross_process_reduction():
    """build_comm=True: each part gets a private JAX sub-mesh over its
    worker-local devices (comm_build flows into the trace) and the spanning
    task combines per-node psums into the global reduction."""
    with ProcessExecutor(n_workers=2, devices_per_worker=2,
                         build_comm=True, heartbeat_interval=0.3) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run(
            [TaskDescription(name="local", ranks=2, fn=_psum_local,
                             tags={"pipeline": "p"}),
             TaskDescription(name="global", ranks=4, fn=_psum_global,
                             tags={"pipeline": "p"})],
            timeout=240)
        by = {t.desc.name: t for t in rep.tasks}
        assert by["local"].state == TaskState.DONE
        assert by["local"].result == 4.0          # 2 ranks x 2 cols
        assert by["global"].result == 8.0         # 4 ranks x 2 cols
        assert len(rep.events("comm_build")) == 2
        assert rep.overhead_total > 0


@needs_cloudpickle
@pytest.mark.integration
def test_unserializable_result_fails_cleanly():
    with ProcessExecutor(n_workers=1, devices_per_worker=1,
                         build_comm=False, heartbeat_interval=0.2) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="bad", ranks=1,
                                        fn=_return_unpicklable, max_retries=0,
                                        tags={"pipeline": "p"})], timeout=60)
        task = rep.tasks[0]
        assert task.state == TaskState.FAILED
        assert task.error


def _return_unpicklable(comm):
    return threading.Lock()     # cannot cross a process boundary
