"""Out-of-core shuffle: raw-buffer peer framing, the Pallas radix-bucket
packing stage, spill-to-disk under REPRO_SHUFFLE_BUDGET, and streamed
merges.

Wire/engine units (no subprocesses) stay in tier-1; everything spawning
worker interpreters — the raw-frame exchange, spill-vs-no-spill identity,
and SIGKILL-mid-shuffle recovery — is ``integration`` and runs in both
halves of the CI ``REPRO_P2P`` matrix.
"""
import signal
import socket
import time

import numpy as np
import pytest

from repro.core import (
    ProcessExecutor, SchedulerSession, TaskDescription, TaskState,
)
from repro.core.executors import protocol
from repro.core.executors.protocol import Channel
from repro.core.executors.worker import _decode_cols, _encode_cols
from repro.dataframe.shuffle import (
    SpillBuffer, _gen_part, hash32, join_task, merge_join_sorted,
    parse_budget, radix_bucket, sort_task,
)


# ---------------------------------------------------------------------------
# wire-layer units: PEER_DATA_RAW framing (no subprocesses)
# ---------------------------------------------------------------------------
def _chan_pair():
    a, b = socket.socketpair()
    return Channel(a), Channel(b)


def test_raw_frame_roundtrip_no_pickle_of_body():
    """A PEER_DATA_RAW frame carries the column bytes verbatim after the
    pickled header; the receiver reassembles identical arrays from the
    stream with np.frombuffer (zero-copy views)."""
    tx, rx = _chan_pair()
    try:
        cols = {"key": np.arange(1000, dtype=np.int32),
                "v0": np.arange(1000, dtype=np.int64) * 3,
                "f": np.linspace(0, 1, 1000, dtype=np.float32)}
        metas, bufs = _encode_cols(cols)
        tx.send_raw(protocol.PEER_DATA_RAW, bufs,
                    uid=7, attempt=0, seq=3, part=1, cols=metas)
        kind, d = rx.recv()
        assert kind == protocol.PEER_DATA_RAW
        assert d["uid"] == 7 and d["seq"] == 3 and d["part"] == 1
        assert d["nbytes"] == sum(v.nbytes for v in cols.values())
        got = _decode_cols(d["cols"], d["payload"])
        assert set(got) == set(cols)
        for k in cols:
            assert got[k].dtype == cols[k].dtype
            np.testing.assert_array_equal(got[k], cols[k])
    finally:
        tx.close()
        rx.close()


def test_raw_frame_interleaves_with_pickled_frames():
    """Raw and pickled frames share one stream and stay self-delimiting:
    pickled / raw / pickled in sequence all parse."""
    tx, rx = _chan_pair()
    try:
        tx.send(protocol.PEER_DATA, uid=1, attempt=0, seq=0, part=0,
                payload=b"x" * 100)
        metas, bufs = _encode_cols({"k": np.arange(50, dtype=np.int32)})
        tx.send_raw(protocol.PEER_DATA_RAW, bufs,
                    uid=1, attempt=0, seq=1, part=0, cols=metas)
        tx.send(protocol.PEER_DATA, uid=1, attempt=0, seq=2, part=0,
                payload=b"y" * 7)
        kinds = [rx.recv()[0] for _ in range(3)]
        assert kinds == [protocol.PEER_DATA, protocol.PEER_DATA_RAW,
                         protocol.PEER_DATA]
    finally:
        tx.close()
        rx.close()


def test_encode_decode_empty_and_2d_columns():
    metas, bufs = _encode_cols({"a": np.zeros((0,), np.int32),
                                "m": np.arange(12, dtype=np.float64
                                               ).reshape(3, 4)})
    payload = b"".join(memoryview(b).cast("B") for b in bufs)
    got = _decode_cols(metas, payload)
    assert got["a"].shape == (0,)
    np.testing.assert_array_equal(got["m"],
                                  np.arange(12, dtype=np.float64
                                            ).reshape(3, 4))


# ---------------------------------------------------------------------------
# engine units: budget, bucketing, spill, merges (no subprocesses)
# ---------------------------------------------------------------------------
def test_parse_budget_suffixes():
    assert parse_budget("32m") == 32 << 20
    assert parse_budget("256K") == 256 << 10
    assert parse_budget("1g") == 1 << 30
    assert parse_budget("12345") == 12345
    assert parse_budget(None, default=7) == 7
    assert parse_budget("") == parse_budget(None)


def test_radix_bucket_matches_mask_selection():
    """Bucket-major chunks == per-bucket mask selection in original row
    order (the kernel's stability), histogram == bincount; verify=True
    additionally cross-checks dest/hist against ref.py bit-for-bit."""
    rng = np.random.default_rng(0)
    cols = {"key": rng.integers(0, 97, 3000, dtype=np.int32),
            "v0": rng.integers(0, 1 << 30, 3000, dtype=np.int64)}
    tgt = (hash32(cols["key"]) % np.uint32(5)).astype(np.int32)
    chunks, hist = radix_bucket(cols, tgt, 5, block=256, verify=True)
    assert [len(c["key"]) for c in chunks] == list(hist)
    np.testing.assert_array_equal(hist, np.bincount(tgt, minlength=5))
    for j, c in enumerate(chunks):
        mask = tgt == j
        np.testing.assert_array_equal(c["key"], cols["key"][mask])
        np.testing.assert_array_equal(c["v0"], cols["v0"][mask])


def test_radix_bucket_empty_input():
    chunks, hist = radix_bucket({"key": np.zeros(0, np.int32)},
                                np.zeros(0, np.int32), 4)
    assert len(chunks) == 4 and all(len(c["key"]) == 0 for c in chunks)
    assert list(hist) == [0, 0, 0, 0]


def test_spillbuffer_threshold_crossing(tmp_path):
    """Runs stay in memory under the budget and spill beyond it — the
    crossing is observable via .spills and the spill files on disk."""
    buf = SpillBuffer(10_000, "key", spill_dir=str(tmp_path))
    small = {"key": np.arange(100, dtype=np.int32)}          # 400 B
    buf.add(small)
    assert buf.spills == 0 and len(list(tmp_path.iterdir())) == 0
    big = {"key": np.arange(5000, dtype=np.int32)}           # 20 KB
    buf.add(big)
    assert buf.spills == 1 and len(list(tmp_path.iterdir())) == 1
    buf.add(small)                                           # still under
    assert buf.spills == 1
    buf.close()


def test_spillbuffer_merges_three_plus_spilled_runs():
    """k-way merge of >= 3 spilled runs equals np.sort of the union, in
    chunks far smaller than any run."""
    rng = np.random.default_rng(1)
    buf = SpillBuffer(0, "key")       # budget 0: every run spills
    allk, allv = [], []
    for _ in range(4):
        r = {"key": rng.integers(0, 500, 1500, dtype=np.int32),
             "v0": rng.integers(0, 9, 1500, dtype=np.int64)}
        allk.append(r["key"])
        allv.append(r["v0"])
        buf.add(r)
    assert buf.spills == 4
    chunks = list(buf.merge_sorted(chunk_rows=113))
    got_k = np.concatenate([c["key"] for c in chunks])
    np.testing.assert_array_equal(got_k, np.sort(np.concatenate(allk)))
    # value rows travel with their keys: per-key value multisets match
    got_v = np.concatenate([c["v0"] for c in chunks])
    ref = sorted(zip(np.concatenate(allk).tolist(),
                     np.concatenate(allv).tolist()))
    assert sorted(zip(got_k.tolist(), got_v.tolist())) == ref
    buf.close()


def test_merge_join_duplicates_across_chunk_boundaries():
    """Streaming merge-join with heavy duplicate keys and chunk sizes that
    force equal-key groups to straddle chunk boundaries."""
    rng = np.random.default_rng(2)
    lk = np.sort(rng.integers(0, 12, 400, dtype=np.int32))
    rk = np.sort(rng.integers(0, 12, 300, dtype=np.int32))
    lv = rng.integers(0, 1000, 400, dtype=np.int64)
    rv = rng.integers(0, 1000, 300, dtype=np.int64)

    def chunked(d, size):
        for i in range(0, len(d["key"]), size):
            yield {k: v[i:i + size] for k, v in d.items()}

    out = list(merge_join_sorted(chunked({"key": lk, "v0": lv}, 7),
                                 chunked({"key": rk, "w0": rv}, 5), "key"))
    got = sorted(zip(np.concatenate([c["key"] for c in out]).tolist(),
                     np.concatenate([c["v0"] for c in out]).tolist(),
                     np.concatenate([c["w0"] for c in out]).tolist()))
    ref = sorted((int(a), int(lv[i]), int(rv[j]))
                 for i, a in enumerate(lk)
                 for j, b in enumerate(rk) if a == b)
    assert got == ref


def test_merge_join_disjoint_sides_empty():
    def one(d):
        yield d
    out = list(merge_join_sorted(
        one({"key": np.array([1, 2], np.int32),
             "v0": np.array([5, 6], np.int64)}),
        one({"key": np.array([3, 4], np.int32),
             "w0": np.array([7, 8], np.int64)}), "key"))
    assert out == []


class _LocalComm:
    """Bare single-part comm stand-in (no executor)."""
    spills = 0


def test_sort_task_spill_vs_no_spill_identical():
    base = {"rows_per_part": 6000, "seed": 9, "collect": True,
            "verify_kernel": True}
    spilled = sort_task(_LocalComm(), {**base, "budget": 4_000,
                                       "chunk_rows": 333})
    resident = sort_task(_LocalComm(), {**base, "budget": 1 << 30})
    assert spilled["spills"] > 0 and resident["spills"] == 0
    assert spilled["sorted"] and resident["sorted"]
    assert spilled["n"] == resident["n"] == 6000
    assert spilled["key_sum"] == resident["key_sum"]
    np.testing.assert_array_equal(spilled["rows"]["key"],
                                  resident["rows"]["key"])
    np.testing.assert_array_equal(
        spilled["rows"]["key"], np.sort(_gen_part(base, 0)["key"]))


def test_join_task_spill_vs_no_spill_identical():
    base = {"rows_per_part": 4000, "key_range": 700, "seed": 9,
            "verify_kernel": True}
    spilled = join_task(_LocalComm(), {**base, "budget": 3_000,
                                       "chunk_rows": 257})
    resident = join_task(_LocalComm(), {**base, "budget": 1 << 30})
    assert spilled["spills"] > 0 and resident["spills"] == 0
    for k in ("n", "key_sum", "v_sum", "w_sum"):
        assert spilled[k] == resident[k], k


def test_budget_env_knob(monkeypatch):
    """REPRO_SHUFFLE_BUDGET drives spilling without a spec override."""
    monkeypatch.setenv("REPRO_SHUFFLE_BUDGET", "2k")
    spec = {"rows_per_part": 3000, "seed": 4}
    out = sort_task(_LocalComm(), spec)
    assert out["spills"] > 0 and out["sorted"]
    monkeypatch.setenv("REPRO_SHUFFLE_BUDGET", "1g")
    assert sort_task(_LocalComm(), spec)["spills"] == 0


# ---------------------------------------------------------------------------
# integration: 2+ worker exchange over the real data plane
# ---------------------------------------------------------------------------
def _numpy_ref_join(spec, n_parts):
    L = {k: np.concatenate([_gen_part(spec, p, 0)[k]
                            for p in range(n_parts)])
         for k in ("key", "v0")}
    rspec = dict(spec)
    rspec["rows_per_part"] = spec.get("right_rows_per_part",
                                      spec["rows_per_part"])
    R = {k: np.concatenate([_gen_part(rspec, p, 1)[k]
                            for p in range(n_parts)])
         for k in ("key", "w0")}
    ol = np.argsort(L["key"], kind="stable")
    lk, lv = L["key"][ol], L["v0"][ol]
    orr = np.argsort(R["key"], kind="stable")
    rk, rv = R["key"][orr], R["w0"][orr]
    lo = np.searchsorted(rk, lk, "left")
    hi = np.searchsorted(rk, lk, "right")
    counts = hi - lo
    n = int(counts.sum())
    li = np.repeat(np.arange(len(lk)), counts)
    ri = lo[li] + (np.arange(n) - (np.cumsum(counts) - counts)[li])
    m = np.uint64(0xFFFFFFFFFFFFFFFF)

    def s(a):
        return int(np.add.reduce(a.astype(np.uint64), dtype=np.uint64) & m)

    return {"n": n, "key_sum": s(lk[li]), "v_sum": s(lv[li]),
            "w_sum": s(rv[ri])}


@pytest.mark.integration
def test_dist_sort_2workers_spills_and_matches_numpy():
    """Tentpole acceptance: 2-worker out-of-core sample sort under a budget
    smaller than the dataset — spill exercised, result equals np.sort of
    the generated input, kernel verified against ref.py on the live path,
    and the spill evidence lands on Task/ExecEvent/executor."""
    spec = {"rows_per_part": 20_000, "seed": 3, "budget": 150_000,
            "collect": True, "verify_kernel": True}
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.3, tick=0.02) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="ooc_sort", ranks=2,
                                        fn=sort_task, args=(spec,))],
                       timeout=180)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE, task.error
        res = task.result
        assert res["sorted"] and res["n"] == 40_000
        exp = np.sort(np.concatenate([_gen_part(spec, p)["key"]
                                      for p in (0, 1)]))
        np.testing.assert_array_equal(res["rows"]["key"], exp)
        # dataset >> budget: the spill path ran, and the counter threads
        # all the way through PART_DONE -> Task -> trace
        assert res["spills"] > 0
        assert task.spills == res["spills"] == ex.spills
        done = [e for e in rep.trace if e.kind == "done"]
        assert done and done[0].spills == float(task.spills)
        if ex.p2p:
            # bucket bytes moved worker-to-worker, not through the hub
            assert task.p2p_bytes > 100_000
            assert ex.hub_relay_bytes < task.p2p_bytes / 10


@pytest.mark.integration
def test_dist_join_2workers_matches_numpy_reference():
    spec = {"rows_per_part": 12_000, "key_range": 3000, "seed": 5,
            "budget": 100_000, "verify_kernel": True}
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.3, tick=0.02) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="ooc_join", ranks=2,
                                        fn=join_task, args=(spec,))],
                       timeout=180)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE, task.error
        ref = _numpy_ref_join(spec, 2)
        for k in ("n", "key_sum", "v_sum", "w_sum"):
            assert task.result[k] == ref[k], k
        assert task.result["spills"] > 0


@pytest.mark.integration
def test_raw_frames_off_same_sort_result(monkeypatch):
    """REPRO_RAW_FRAMES=0 (the A/B knob): the identical workload completes
    over pickled frames with the identical result."""
    monkeypatch.setenv("REPRO_RAW_FRAMES", "0")
    spec = {"rows_per_part": 8000, "seed": 3, "budget": 60_000,
            "collect": True}
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.3, tick=0.02) as ex:
        assert ex.raw_frames is False
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
        rep = sess.run([TaskDescription(name="ooc_sort", ranks=2,
                                        fn=sort_task, args=(spec,))],
                       timeout=180)
        task = rep.tasks[0]
        assert task.state == TaskState.DONE, task.error
        exp = np.sort(np.concatenate([_gen_part(spec, p)["key"]
                                      for p in (0, 1)]))
        np.testing.assert_array_equal(task.result["rows"]["key"], exp)


@pytest.mark.integration
def test_sigkill_mid_shuffle_recovers_same_sorted_output():
    """Kill-mid-shuffle recovery: SIGKILL a worker while its SpillBuffer
    holds spilled buckets (the stall_s hook parks the part between spill
    and merge).  The loss surfaces as the targeted device_failure, the
    task retries with exclusion on the survivors, and — the input being
    deterministic per (seed, part) — reproduces the identical sorted
    output."""
    spec = {"rows_per_part": 10_000, "seed": 13, "budget": 50_000,
            "collect": True, "stall_s": 3.0}
    with ProcessExecutor(n_workers=3, devices_per_worker=1, build_comm=False,
                         heartbeat_interval=0.2, tick=0.02) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="victim", ranks=2, fn=sort_task,
                                     args=(spec,), max_retries=2)])
        time.sleep(1.2)      # parts are inside the stall, spills on disk
        victims = {d.worker
                   for t in sess.tasks for d in t.devices} or {"w0"}
        ex.kill_worker(sorted(victims)[0], signal.SIGKILL)
        rep = sess.drain(timeout=180).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE, task.error
        assert task.retries >= 1
        assert len(rep.events("device_failure")) == 1
        assert task.result["sorted"] and task.result["n"] == 20_000
        exp = np.sort(np.concatenate([_gen_part(spec, p)["key"]
                                      for p in (0, 1)]))
        np.testing.assert_array_equal(task.result["rows"]["key"], exp)
        assert task.result["spills"] > 0
