"""Importable result-cache payloads for test_checkpoint_resume.

Kept OUT of the test module on purpose: the test module is registered with
``cloudpickle.register_pickle_by_value`` (its proc payloads must ship to
worker processes by value), and by-value pickling of a function is not
byte-stable across intervening imports in one process — the result-cache
key would drift.  An importable module-level function pickles by reference
(module + qualname), so its digest is deterministic — which is also the
realistic shape of cacheable production payloads.
"""
import numpy as np


def counted(comm, marker, scale=2.0):
    # execution counter lives in a side file, NOT a global the pickled
    # payload could capture into its digest
    with open(marker, "a") as f:
        f.write("x\n")
    rng = np.random.default_rng(7)
    return (rng.standard_normal(16) * scale).astype(np.float32)
