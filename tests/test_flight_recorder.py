"""Flight recorder: worker spans, telemetry heartbeats, durable JSONL
capture, Perfetto export, and replay loading.

Pure-unit coverage (recorders, metrics registry, path resolution, JSONL
round-trip on the virtual clock, torn-tail tolerance, exporter shape) stays
in tier-1; the 2-worker process-executor round-trips are ``integration``.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path

import pytest

from repro.core import (
    ProcessExecutor, ResourceManager, SchedulerSession, SimOptions,
    TaskDescription, TaskState, VirtualClockExecutor,
)
from repro.core.executors import serialize
from repro.obs import (
    MetricsRegistry, NullRecorder, SpanRecorder, align, bound,
    current_recorder, export_perfetto, load_trace, resolve_trace_path,
    rss_mb,
)
from repro.obs.spans import SPAN_KINDS, WAIT_KINDS

sys.path.insert(0, str(Path(__file__).resolve().parent))
from _hypothesis_compat import given, settings, st  # noqa: E402

ROOT = Path(__file__).resolve().parents[1]

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle

    cloudpickle.register_pickle_by_value(sys.modules[__name__])

needs_cloudpickle = pytest.mark.skipif(
    not serialize.HAVE_CLOUDPICKLE,
    reason="cloudpickle needed to ship test-local payload functions")


def _trace_summary(report):
    sys.path.insert(0, str(ROOT))
    from benchmarks.common import trace_summary
    return trace_summary(report)


# ---------------------------------------------------------------------------
# span recorder units
# ---------------------------------------------------------------------------
def test_span_recorder_records_and_exports():
    rec = SpanRecorder()
    with rec.span("compute"):
        pass
    rec.add("merge", 1.0, 2.5)
    out = rec.export()
    assert [k for k, _, _ in out] == ["compute", "merge"]
    assert all(t1 >= t0 for _, t0, t1 in out)
    assert set(k for k, _, _ in out) <= set(SPAN_KINDS)


def test_null_recorder_is_inert_default():
    # outside an instrumented part the thread-local recorder is a no-op —
    # shuffle helpers can record unconditionally
    rec = current_recorder()
    assert isinstance(rec, NullRecorder)
    with rec.span("spill_write"):
        rec.add("merge", 0.0, 1.0)
    assert rec.export() == []


def test_bound_recorder_is_thread_local():
    rec = SpanRecorder()
    seen = {}

    def other_thread():
        seen["other"] = current_recorder()

    with bound(rec):
        assert current_recorder() is rec
        t = threading.Thread(target=other_thread)
        t.start()
        t.join()
    assert isinstance(seen["other"], NullRecorder)   # binding didn't leak
    assert isinstance(current_recorder(), NullRecorder)


def test_align_tags_and_shifts():
    spans = [("compute", 1.0, 2.0), ("p2p_recv", 1.2, 1.4)]
    out = align(spans, 10.0, worker="w0", part=1, uid=7, task="t")
    assert out[0] == {"kind": "compute", "t0": 11.0, "t1": 12.0,
                      "worker": "w0", "part": 1, "uid": 7, "task": "t"}
    assert out[1]["kind"] == "p2p_recv" and out[1]["t0"] == 11.2


@given(st.lists(st.tuples(st.sampled_from(SPAN_KINDS),
                          st.floats(0, 1e6),
                          st.floats(0, 60)),
                max_size=20),
       st.floats(-1e9, 1e9))
@settings(max_examples=200, deadline=None)
def test_align_clock_offset_preserves_order_and_nesting(raw, offset):
    """Clock-offset alignment is a pure shift: every <=-relation between
    endpoints (ordering, monotonicity, span nesting) must survive, whatever
    the worker's offset — the property the merged multi-worker timeline
    rests on (IEEE rounding of x+c is monotone in x)."""
    spans = [(k, t0, t0 + dur) for k, t0, dur in raw]
    out = align(spans, offset, worker="w")
    assert len(out) == len(spans)
    ends = [e for _, t0, t1 in spans for e in (t0, t1)]
    ends2 = [e for s in out for e in (s["t0"], s["t1"])]
    for i in range(len(ends)):
        for j in range(len(ends)):
            if ends[i] <= ends[j]:
                assert ends2[i] <= ends2[j]
    for s in out:
        assert s["t0"] <= s["t1"]      # spans never invert


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_metrics_registry_parent_chaining():
    worker = MetricsRegistry()
    part = MetricsRegistry(parent=worker)
    part.inc("hub_calls")
    part.inc("hub_calls", 2)
    part2 = MetricsRegistry(parent=worker)
    part2.inc("hub_calls", 5)
    assert part.get("hub_calls") == 3
    assert worker.get("hub_calls") == 8       # lifetime totals accumulate


def test_metrics_set_counter_keeps_delta_semantics():
    """``comm.spills += n`` compiles to a read + set_counter: the parent
    must see only the DELTA, not the re-applied absolute value."""
    worker = MetricsRegistry()
    part = MetricsRegistry(parent=worker)
    part.set_counter("spills", 4)
    part.set_counter("spills", 4)             # idempotent re-set: no delta
    part.set_counter("spills", 6)
    assert part.get("spills") == 6
    assert worker.get("spills") == 6


def test_metrics_gauges_snapshot_and_rss():
    reg = MetricsRegistry()
    reg.inc("p2p_bytes", 100)
    reg.gauge("depth", lambda: 3)
    reg.gauge("broken", lambda: 1 / 0)
    snap = reg.snapshot()
    assert snap["p2p_bytes"] == 100 and snap["depth"] == 3
    assert snap["broken"] == -1               # raising gauge never kills HB
    assert rss_mb() > 1.0


# ---------------------------------------------------------------------------
# trace path resolution
# ---------------------------------------------------------------------------
def test_resolve_trace_path_modes(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_TRACE", raising=False)
    assert resolve_trace_path() is None
    f = tmp_path / "sub" / "run.jsonl"
    assert resolve_trace_path(str(f)) == str(f)
    assert f.parent.is_dir()                  # parent dirs are created
    monkeypatch.setenv("REPRO_TRACE", str(tmp_path / "env.jsonl"))
    assert resolve_trace_path() == str(tmp_path / "env.jsonl")
    assert resolve_trace_path(str(f)) == str(f)   # explicit beats env
    # directory mode: one unique file per session, never a clobber
    d = tmp_path / "traces"
    p1 = resolve_trace_path(str(d) + os.sep)
    Path(p1).touch()
    p2 = resolve_trace_path(str(d))
    assert p1 != p2
    assert Path(p1).parent == d and p1.endswith(".jsonl")


# ---------------------------------------------------------------------------
# JSONL round-trip on the virtual clock (same schema as proc, no spans)
# ---------------------------------------------------------------------------
def _sim_session(trace_path=None, n_devices=4):
    return SchedulerSession(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0)),
        ResourceManager(list(range(n_devices))), trace_path=trace_path)


def _sim_descs(n=6):
    return [TaskDescription(name=f"t{i}", ranks=1 + i % 2, fn=None,
                            duration_model=lambda r: 0.2,
                            tags={"pipeline": "p"})
            for i in range(n)]


def test_sim_jsonl_roundtrip_and_replay(tmp_path):
    path = tmp_path / "sim.jsonl"
    rep = _sim_session(str(path)).run(_sim_descs())
    rec = load_trace(str(path))
    assert rec.meta["backend"] == "VirtualClockExecutor"
    assert rec.meta["n_devices"] == 4
    assert rec.spans == [] and rec.telemetry == []   # same schema, empty
    live, loaded = _trace_summary(rep), _trace_summary(rec)
    assert loaded == live
    assert loaded["n_done"] == 6
    assert "compute_s" not in loaded          # span keys only when spans
    # replay: the recorded arrival/duration skeleton re-runs noise-free on
    # the virtual clock with an identical schedule shape
    replayed = _trace_summary(rec.replay())
    for k in ("n_submit", "n_dispatch", "n_done"):
        assert replayed[k] == live[k]


def test_repro_trace_env_directory_capture(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TRACE", str(tmp_path))
    _sim_session().run(_sim_descs(2))
    _sim_session().run(_sim_descs(2))
    files = sorted(tmp_path.glob("trace-*.jsonl"))
    assert len(files) == 2                    # one unique file per session
    assert _trace_summary(load_trace(str(files[0])))["n_done"] == 2


def test_torn_final_line_is_skipped(tmp_path):
    path = tmp_path / "torn.jsonl"
    rep = _sim_session(str(path)).run(_sim_descs(3))
    whole = _trace_summary(load_trace(str(path)))
    with open(path, "a", encoding="utf-8") as f:
        f.write('{"type": "event", "kind": "disp')   # SIGKILL mid-write
    assert _trace_summary(load_trace(str(path))) == whole == _trace_summary(rep)


def test_sigkill_mid_run_leaves_parseable_prefix(tmp_path):
    """A run killed -9 mid-flight (no close(), no flush call) must leave a
    JSONL prefix that load_trace fully parses — the crash-forensics
    contract of the line-buffered writer."""
    path = tmp_path / "killed.jsonl"
    child = (
        "import os, signal, sys\n"
        "from repro.core import (ResourceManager, SchedulerSession,\n"
        "    SimOptions, TaskDescription, VirtualClockExecutor)\n"
        "sess = SchedulerSession(VirtualClockExecutor(SimOptions(noise=0.0)),\n"
        f"    ResourceManager(list(range(2))), trace_path={str(path)!r})\n"
        "sess.submit([TaskDescription(name=f't{i}', ranks=1, fn=None,\n"
        "    duration_model=lambda r: 0.1, tags={'pipeline': 'p'})\n"
        "    for i in range(8)])\n"
        "sess.wait_any()\n"
        "os.kill(os.getpid(), signal.SIGKILL)\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", child], env=env, timeout=120)
    assert r.returncode == -signal.SIGKILL
    rec = load_trace(str(path))
    assert rec.meta.get("backend") == "VirtualClockExecutor"
    s = _trace_summary(rec)
    assert s["n_submit"] == 8 and s["n_dispatch"] >= 1
    # truncated runs still replay: unfinished tasks get zero durations
    assert _trace_summary(rec.replay())["n_submit"] == 8


# ---------------------------------------------------------------------------
# perfetto export
# ---------------------------------------------------------------------------
def _fake_spans():
    return (align([("launch_recv", 0.00, 0.01), ("deserialize", 0.01, 0.02),
                   ("compute", 0.02, 0.30), ("p2p_recv", 0.05, 0.12)],
                  0.0, worker="w0", part=0, uid=0, task="t0")
            + align([("compute", 0.02, 0.25), ("spill_write", 0.10, 0.15)],
                    0.0, worker="w1", part=1, uid=0, task="t0"))


def test_perfetto_export_shape(tmp_path):
    rep = _sim_session(str(tmp_path / "p.jsonl")).run(_sim_descs(4))
    rec = load_trace(str(tmp_path / "p.jsonl"))
    rec.spans.extend(_fake_spans())
    rec.telemetry.append({"worker": "w0", "t": 0.1, "queue_depth": 2,
                          "rss_mb": 17.5, "label": "not-a-number"})
    out = tmp_path / "p.trace.json"
    doc = export_perfetto(rec, str(out))
    assert json.loads(out.read_text()) == doc
    ev = doc["traceEvents"]
    procs = {e["args"]["name"] for e in ev if e["ph"] == "M"}
    assert {"scheduler", "worker w0", "worker w1"} <= procs
    tasks = [e for e in ev if e["ph"] == "X" and e["cat"] == "task"]
    assert len(tasks) == 4 and all(e["dur"] > 0 for e in tasks)
    spans = [e for e in ev if e["ph"] == "X" and e["cat"] == "span"]
    assert {e["name"] for e in spans} == {"launch_recv", "deserialize",
                                          "compute", "p2p_recv",
                                          "spill_write"}
    counters = {e["name"] for e in ev if e["ph"] == "C"}
    assert counters == {"queue_depth", "rss_mb"}   # strings are skipped
    assert all(e["ts"] >= 0 for e in ev if "ts" in e)


def test_perfetto_cli_default_output(tmp_path, capsys):
    from repro.obs.perfetto import main
    path = tmp_path / "run.jsonl"
    _sim_session(str(path)).run(_sim_descs(2))
    main([str(path)])
    out = tmp_path / "run.trace.json"
    assert out.exists()
    assert "traceEvents" in json.loads(out.read_text())
    assert str(out) in capsys.readouterr().out


# ---------------------------------------------------------------------------
# trace_summary / trace_gantt span paths
# ---------------------------------------------------------------------------
class _FakeReport:
    def __init__(self, spans):
        self.trace = []
        self.tasks = []
        self.spans = spans
        self.telemetry = []


def test_trace_summary_span_derived_breakdown():
    s = _trace_summary(_FakeReport(_fake_spans()))
    assert s["compute_s"] == pytest.approx(0.28 + 0.23)
    assert s["comm_wait_s"] == pytest.approx(0.07)
    assert s["p2p_fallbacks"] == 0 and s["hub_relay_bytes"] == 0


def test_trace_gantt_span_lanes_and_heuristic_fallback():
    sys.path.insert(0, str(ROOT))
    from benchmarks.report import trace_gantt
    txt = trace_gantt(_FakeReport(_fake_spans()), width=40)
    assert "span-traced" in txt and "2 workers" in txt
    assert "w0.0" in txt and "w1.0" in txt
    assert "~" in txt                         # p2p_recv wait shading
    assert "overall compute utilization" in txt
    # span-less reports keep the heuristic event-stream path
    rep = _sim_session().run(_sim_descs(3))
    assert rep.spans == []
    assert "devices)" in trace_gantt(rep) and "span-traced" not in \
        trace_gantt(rep)


# ---------------------------------------------------------------------------
# heartbeat knob resolution (no worker spawn)
# ---------------------------------------------------------------------------
def test_heartbeat_knob_resolution(monkeypatch):
    monkeypatch.delenv("REPRO_HEARTBEAT", raising=False)
    ex = ProcessExecutor(n_workers=0, build_comm=False)
    assert ex.hb_interval == 0.5 and ex.hb_timeout == 2.5
    monkeypatch.setenv("REPRO_HEARTBEAT", "0.1")
    ex = ProcessExecutor(n_workers=0, build_comm=False)
    assert ex.hb_interval == 0.1
    assert ex.hb_timeout == 2.0               # liveness floor holds
    ex = ProcessExecutor(n_workers=0, build_comm=False, heartbeat=2.0)
    assert ex.hb_interval == 2.0              # kwarg beats env
    assert ex.hb_timeout == 10.0              # timeout tracks the interval
    ex = ProcessExecutor(n_workers=0, build_comm=False, heartbeat=2.0,
                         heartbeat_timeout=3.0)
    assert ex.hb_timeout == 3.0               # explicit decoupling


# ---------------------------------------------------------------------------
# payloads shipped to workers (module-level, pickled by value)
# ---------------------------------------------------------------------------
def _gather_probe(comm, n_coll=2):
    for _ in range(n_coll):
        comm.allgather(comm.global_ranks)
    return comm.size


def _slow_probe(comm, dur=0.6):
    time.sleep(dur)
    return comm.allgather(comm.rank)


# ---------------------------------------------------------------------------
# process-executor round trips (subprocess-spawning)
# ---------------------------------------------------------------------------
@needs_cloudpickle
@pytest.mark.integration
def test_proc_jsonl_roundtrip_counters_spans_and_replay(tmp_path):
    """2-worker live run with capture on: the JSONL trace must reproduce
    the live report's trace_summary EXACTLY (counters and span-derived
    seconds), carry clock-aligned worker spans, and replay through the
    virtual clock with an identical schedule shape."""
    path = tmp_path / "proc.jsonl"
    with ProcessExecutor(n_workers=2, devices_per_worker=2, build_comm=False,
                         heartbeat=0.2, tick=0.02) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02,
                                trace_path=str(path))
        rep = sess.run(
            [TaskDescription(name="span", ranks=4, fn=_gather_probe,
                             tags={"pipeline": "p"}),
             TaskDescription(name="solo", ranks=1, fn=_gather_probe,
                             tags={"pipeline": "p"})],
            timeout=120)
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    live = _trace_summary(rep)
    # the 4-rank task splits into 2 parts; each part's 2 allgathers are hub
    # round-trips, summed across parts by the tracker
    assert live["hub_calls"] == 4
    assert live["compute_s"] > 0

    rec = load_trace(str(path))
    assert rec.meta["backend"] == "ProcessExecutor"
    assert _trace_summary(rec) == live
    kinds = {s["kind"] for s in rec.spans}
    assert {"launch_recv", "deserialize", "compute"} <= kinds <= \
        set(SPAN_KINDS)
    assert {s["worker"] for s in rec.spans} == {"w0", "w1"}
    # hub collectives surface as wait spans on the spanning task's parts
    assert any(s["kind"] in WAIT_KINDS and s["task"] == "span"
               for s in rec.spans)
    for s in rec.spans:                       # aligned to the parent clock
        assert s["t1"] >= s["t0"] >= 0
    replayed = _trace_summary(rec.replay())
    for k in ("n_submit", "n_dispatch", "n_done"):
        assert replayed[k] == live[k] == 2


@needs_cloudpickle
@pytest.mark.integration
def test_heartbeat_telemetry_flows_into_trace(tmp_path):
    """A task outliving the heartbeat interval: gauge snapshots must arrive
    as ``telemetry`` trace events, land in the JSONL, and feed Perfetto
    counter tracks."""
    path = tmp_path / "hb.jsonl"
    with ProcessExecutor(n_workers=2, devices_per_worker=1, build_comm=False,
                         heartbeat=0.1, tick=0.02) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02,
                                trace_path=str(path))
        rep = sess.run([TaskDescription(name="slow", ranks=2, fn=_slow_probe,
                                        tags={"pipeline": "p"})], timeout=120)
    assert rep.tasks[0].state == TaskState.DONE
    assert rep.telemetry                      # at least one beat landed
    sample = rep.telemetry[0]
    assert {"worker", "t", "queue_depth", "rss_mb"} <= set(sample)
    assert sample["rss_mb"] > 1.0
    assert {r["worker"] for r in rep.telemetry} <= {"w0", "w1"}
    tel_events = rep.events("telemetry")
    assert tel_events and tel_events[0].data.get("queue_depth") is not None

    rec = load_trace(str(path))
    assert len(rec.telemetry) == len(rep.telemetry)
    counters = {e["name"] for e in export_perfetto(rec)["traceEvents"]
                if e["ph"] == "C"}
    assert "queue_depth" in counters and "rss_mb" in counters
