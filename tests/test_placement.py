"""Topology-aware placement layer: Topology reports, pack/spread policies,
ResourceManager.allocate_placed, property-based invariants under CHANGING
topologies (elastic grow/retire reshapes the node map between calls), and
the communicator fixes that ride along (sub() ValueError, _factor_shape
degenerate-axis normalization)."""
import pytest

from repro.core import (
    PACK, SPREAD, Communicator, ProcDevice, ProcessExecutor, ResourceManager,
    SchedulerSession, SimOptions, TaskDescription, TaskState, ThreadExecutor,
    Topology, VirtualClockExecutor,
)
from repro.core.communicator import _factor_shape, degenerate_axes
from repro.core.placement import plan
from tests._hypothesis_compat import given, settings, st


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------
def test_topology_nodes_and_grouping():
    topo = Topology({"w0": [0, 1], "w1": [2, 3]})
    assert topo.n_nodes == 2
    assert topo.node_of(1) == "w0" and topo.node_of(3) == "w1"
    assert topo.node_of("stranger") is None
    groups = topo.group([3, 0, 2, 1])
    assert groups == {"w1": [3, 2], "w0": [0, 1]}   # order kept within node


def test_topology_unknown_devices_get_private_nodes():
    """Pack must never co-locate devices the topology knows nothing about."""
    topo = Topology({"w0": [0]})
    groups = topo.group([0, "x", "y"])
    assert groups["w0"] == [0]
    assert [v for k, v in groups.items() if k != "w0"] == [["x"], ["y"]]


# ---------------------------------------------------------------------------
# plan: the policy itself
# ---------------------------------------------------------------------------
def test_plan_spread_is_legacy_flat_order_with_exclude_last():
    free = [0, 1, 2, 3]
    assert plan(2, free) == [0, 1]
    assert plan(2, free, policy=SPREAD) == [0, 1]
    # excluded devices are chosen only when nothing else fits
    assert plan(3, free, policy=SPREAD, exclude={0, 1}) == [2, 3, 0]
    # a topology does not change spread: it is the topology-blind baseline
    topo = Topology({"w0": [0, 1], "w1": [2, 3]})
    assert plan(2, free, topo, SPREAD) == [0, 1]


def test_plan_pack_best_fit_single_node():
    topo = Topology({"w0": [0, 1], "w1": [2, 3, 4]})
    # n=2 fits both nodes; best fit = fewest free devices = w0
    assert plan(2, [0, 1, 2, 3, 4], topo, PACK) == [0, 1]
    # with w0 fragmented to one free device, only w1 fits n=2
    assert plan(2, [1, 2, 3, 4], topo, PACK) == [2, 3]


def test_plan_pack_spans_fewest_nodes_when_no_single_fit():
    topo = Topology({"w0": [0], "w1": [1, 2], "w2": [3, 4, 5]})
    # n=5: no node fits; fill from the largest-free nodes first -> w2 + w1
    assert plan(5, [0, 1, 2, 3, 4, 5], topo, PACK) == [3, 4, 5, 1, 2]


def test_plan_pack_prefers_clean_nodes_under_exclusion():
    """A node with enough non-excluded devices beats a smaller node whose
    free devices include ones a prior attempt failed on."""
    topo = Topology({"w0": [0, 1], "w1": [2, 3, 4]})
    got = plan(2, [0, 1, 2, 3, 4], topo, PACK, exclude={0, 1})
    assert got == [2, 3]
    # when every node is tainted, fall back to best fit anyway
    assert plan(2, [0, 1, 2, 3, 4], topo, PACK,
                exclude={0, 1, 2, 3, 4}) == [0, 1]


def test_plan_pack_spanning_avoids_excluded_devices():
    """A spanning allocation must taint as few devices as possible: with
    node w0 fully excluded (e.g. a sick worker a prior attempt failed on),
    the plan drains the clean node first and takes only the unavoidable
    remainder from the tainted one — never leaving a clean device idle in
    favour of a failed one."""
    topo = Topology({"w0": [0, 1, 2], "w1": [3, 4]})
    got = plan(4, [0, 1, 2, 3, 4], topo, PACK, exclude={0, 1, 2})
    assert got == [3, 4, 0, 1]
    # when the clean devices alone suffice, excluded ones are not touched
    # at all, even if that costs one extra node
    topo2 = Topology({"w0": [0, 1, 2], "w1": [3, 4], "w2": [5]})
    got = plan(3, [0, 1, 2, 3, 4, 5], topo2, PACK, exclude={0, 1, 2})
    assert got == [3, 4, 5]


def test_plan_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown placement policy"):
        plan(1, [0, 1], policy="nearest")


def test_plan_overdraw_raises():
    """A plan over fewer free devices than requested (e.g. a direct call
    racing an elastic retire) must fail loudly, never under-allocate."""
    with pytest.raises(ValueError, match="want 3"):
        plan(3, [0, 1], Topology({"w0": [0, 1]}), PACK)


# ---------------------------------------------------------------------------
# property-based invariants: plan() under arbitrary / CHANGING topologies
# (skip cleanly when hypothesis is not installed — tests/_hypothesis_compat)
# ---------------------------------------------------------------------------
#: arbitrary node -> device-count maps, like an elastic pilot's worker set
_NODE_MAP = st.dictionaries(
    st.sampled_from([f"w{i}" for i in range(6)]),
    st.integers(min_value=1, max_value=5), min_size=1, max_size=5)


def _devices_of(node_map):
    return [(node, i) for node, k in sorted(node_map.items())
            for i in range(k)]


def _check_plan_invariants(n, free, topo, policy, exclude):
    got = plan(n, list(free), topo, policy, exclude)
    # exactness: n devices, all from the free list, no duplicates
    assert len(got) == n
    assert len(set(got)) == n
    assert set(got) <= set(free)
    clean = [d for d in free if d not in exclude]
    if len(clean) >= n:
        # the retry-with-exclusion contract: excluded devices are touched
        # only when the clean ones cannot cover the request
        assert not set(got) & set(exclude)
    if policy == PACK:
        # single-node guarantee: whenever ANY node can host all n ranks,
        # pack never spans.  The exclusion contract outranks packing, so
        # the fit is judged over the pool pack actually plans on: clean
        # devices alone whenever they can cover the request
        pool = clean if len(clean) >= n else free
        if any(len(devs) >= n for devs in topo.group(pool).values()):
            assert len(topo.group(got)) == 1
    # determinism: placement is a pure function of its inputs
    assert plan(n, list(free), topo, policy, exclude) == got
    return got


@settings(max_examples=60, deadline=None)
@given(node_map=_NODE_MAP, data=st.data())
def test_plan_invariants_hold_for_arbitrary_topologies(node_map, data):
    devices = _devices_of(node_map)
    topo = Topology({node: [d for d in devices if d[0] == node]
                     for node in node_map})
    free = data.draw(st.permutations(devices), label="free")
    n = data.draw(st.integers(min_value=1, max_value=len(free)), label="n")
    exclude = set(data.draw(st.lists(st.sampled_from(devices), unique=True),
                            label="exclude"))
    for policy in (SPREAD, PACK):
        _check_plan_invariants(n, free, topo, policy, exclude)


@settings(max_examples=40, deadline=None)
@given(node_map=_NODE_MAP, data=st.data())
def test_plan_invariants_survive_topology_changes_between_calls(node_map,
                                                                data):
    """The elastic scenario: allocate under one topology, then a node joins
    (grow) and one drains away (retire) before the next allocation — the
    invariants must hold for BOTH calls, including when the second free
    list is missing the first call's devices and spans nodes the first
    topology never knew."""
    devices = _devices_of(node_map)
    topo = Topology({node: [d for d in devices if d[0] == node]
                     for node in node_map})
    n1 = data.draw(st.integers(min_value=1, max_value=len(devices)),
                   label="n1")
    policy = data.draw(st.sampled_from([SPREAD, PACK]), label="policy")
    taken = _check_plan_invariants(n1, devices, topo, policy, set())

    # grow: a brand-new node joins; retire: one original node stops leasing
    grown_k = data.draw(st.integers(min_value=1, max_value=4), label="grown")
    grown = [("w9", i) for i in range(grown_k)]
    retired = data.draw(st.sampled_from(sorted(node_map)), label="retired")
    free2 = [d for d in devices
             if d not in set(taken) and d[0] != retired] + grown
    topo2 = Topology({**{node: [d for d in devices if d[0] == node]
                         for node in node_map if node != retired},
                      "w9": grown})
    if not free2:
        return
    n2 = data.draw(st.integers(min_value=1, max_value=len(free2)),
                   label="n2")
    exclude2 = set(data.draw(st.lists(st.sampled_from(devices + grown),
                                      unique=True), label="exclude2"))
    got2 = _check_plan_invariants(n2, free2, topo2, policy, exclude2)
    # nothing from the retired node (gone from the free list) nor from the
    # first allocation can reappear
    assert not {d for d in got2 if d[0] == retired}
    assert not set(got2) & set(taken)


# ---------------------------------------------------------------------------
# ResourceManager.allocate_placed + the executor topology reports
# ---------------------------------------------------------------------------
def test_allocate_is_shim_over_allocate_placed():
    a, b = ResourceManager(range(6)), ResourceManager(range(6))
    assert a.allocate(3, exclude={0}) == b.allocate_placed(3, exclude={0})
    assert a.n_free == b.n_free == 3


def test_spread_free_list_evolution_matches_legacy_allocate():
    """Bit-for-bit reproduction includes the free list's internal order:
    the historical allocate() persisted its excluded-last reordering into
    the remaining pool, so the NEXT allocation saw [3, 1], not [1, 3]."""
    rm = ResourceManager([0, 1, 2, 3])
    assert rm.allocate(2, exclude={1}) == (0, 2)
    assert rm.allocate(2) == (3, 1)      # the reorder persisted


def test_allocate_placed_pack_with_callable_topology():
    rm = ResourceManager([ProcDevice("w0", 0), ProcDevice("w0", 1),
                          ProcDevice("w1", 0), ProcDevice("w1", 1)])
    ex = ProcessExecutor(n_workers=2)          # never started: topology() is
    # pure classification by handle, no worker processes involved
    blocker = rm.allocate_placed(1, topology=ex.topology, policy=PACK)
    assert blocker == (ProcDevice("w0", 0),)
    got = rm.allocate_placed(2, topology=ex.topology, policy=PACK)
    assert got == (ProcDevice("w1", 0), ProcDevice("w1", 1))


def test_thread_executor_topology_is_one_node():
    topo = ThreadExecutor(build_comm=False).topology(["d0", "d1"])
    assert topo.n_nodes == 1 and topo.node_of("d1") == "node0"


def test_virtual_executor_synthetic_topology_is_stable_on_subsets():
    ex = VirtualClockExecutor(SimOptions(devices_per_node=2))
    full = ex.topology(range(6))
    assert full.nodes == {"n0": (0, 1), "n1": (2, 3), "n2": (4, 5)}
    # classifying a fragmented free list maps devices to the SAME nodes
    sub = ex.topology([5, 1, 2])
    assert sub.node_of(5) == "n2" and sub.node_of(1) == "n0"
    # devices_per_node=0 (default) -> the historical one-flat-node view
    assert VirtualClockExecutor(SimOptions()).topology([0, 1]).n_nodes == 1


def test_pack_placement_end_to_end_on_virtual_nodes():
    """Dispatch consults the placement layer: with dev 0 held by a blocker,
    a 2-rank task under pack lands on node n1's devices (2, 3) instead of
    straddling (1, 2) as the flat order would."""
    opts = SimOptions(noise=0.0, overhead_model=lambda r: 0.0,
                      devices_per_node=2)
    sess = SchedulerSession(VirtualClockExecutor(opts),
                            ResourceManager(range(4)), placement=PACK)
    blk, two = sess.submit([
        TaskDescription(name="blk", ranks=1, fn=None,
                        duration_model=lambda r: 5.0,
                        tags={"pipeline": "p"}),
        TaskDescription(name="two", ranks=2, fn=None,
                        duration_model=lambda r: 1.0,
                        tags={"pipeline": "p"})])
    assert blk.devices == (0,)
    assert two.devices == (2, 3)
    assert two.placement == PACK
    rep = sess.drain().close()
    assert all(t.state == TaskState.DONE for t in rep.tasks)


def test_spread_placement_reproduces_flat_allocation():
    """Same scenario under spread (the default): today's flat first-free
    order, i.e. the 2-rank task straddles the synthetic nodes."""
    opts = SimOptions(noise=0.0, overhead_model=lambda r: 0.0,
                      devices_per_node=2)
    sess = SchedulerSession(VirtualClockExecutor(opts),
                            ResourceManager(range(4)))
    _, two = sess.submit([
        TaskDescription(name="blk", ranks=1, fn=None,
                        duration_model=lambda r: 5.0,
                        tags={"pipeline": "p"}),
        TaskDescription(name="two", ranks=2, fn=None,
                        duration_model=lambda r: 1.0,
                        tags={"pipeline": "p"})])
    assert two.devices == (1, 2)
    sess.drain().close()


def test_unknown_placement_rejected_at_session_start():
    with pytest.raises(ValueError, match="unknown placement"):
        SchedulerSession(VirtualClockExecutor(SimOptions()),
                         ResourceManager(range(2)), placement="closest")


def test_placement_recorded_on_live_communicator():
    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["d0"]), placement=PACK)
    rep = sess.run([TaskDescription(name="t", ranks=1,
                                    fn=lambda comm: comm.placement,
                                    tags={"pipeline": "p"})], timeout=30)
    assert rep.tasks[0].result == PACK


# ---------------------------------------------------------------------------
# communicator satellites: sub() errors, _factor_shape degeneracy
# ---------------------------------------------------------------------------
def _comm(axes, shape):
    return Communicator(mesh=None, devices=tuple(range(sum(shape))),
                        axes=axes, shape=shape, build_seconds=0.0)


def test_sub_unknown_axis_raises_value_error_naming_axes():
    comm = _comm(("df", "mp"), (4, 2))
    assert comm.sub("df") == 4 and comm.sub("mp") == 2
    with pytest.raises(ValueError, match=r"'tp'.*\('df', 'mp'\)"):
        comm.sub("tp")


def test_factor_shape_normalizes_largest_first():
    assert _factor_shape(12, 1) == (12,)
    assert _factor_shape(12, 2) == (4, 3)
    assert _factor_shape(12, 3) == (3, 2, 2)


def test_factor_shape_prime_is_detectably_degenerate():
    """Prime n cannot fill 2 axes: the size-1 axis now TRAILS ((n, 1), never
    (1, n)) and degenerate_axes flags it so callers can react instead of
    silently partitioning work along a no-op axis."""
    assert _factor_shape(7, 2) == (7, 1)
    assert degenerate_axes((7, 1)) == (1,)
    assert degenerate_axes((4, 3)) == ()
    # a genuinely single-rank mesh has no usable parallelism anywhere;
    # nothing to flag
    assert _factor_shape(1, 2) == (1, 1)
    assert degenerate_axes((1, 1)) == ()
    assert degenerate_axes((1,)) == ()
    comm = _comm(("df", "mp"), (7, 1))
    assert comm.degenerate_axes == ("mp",)
