"""Transport tiers for spanning collectives (PR 8).

Covers the three tiers end to end: generic zero-copy raw framing
(PEER_DATA_GEN), the wide-task ring allgather, and the same-host
shared-memory handoff (PEER_DATA_SHM) — plus the invariants every tier must
preserve: per-payload fallback ladder, bit-identical results across tiers,
SIGKILL mid-collective -> targeted device_failure -> retry-with-exclusion,
and zero ``/dev/shm`` residue after clean finish, retire, and kill.

Wire-layer units (no subprocesses) stay in tier-1; everything spawning
worker interpreters is ``integration`` (CI runs those in both halves of the
``REPRO_SHM`` matrix).
"""
import signal
import sys
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import (
    ProcessExecutor, SchedulerSession, TaskDescription, TaskState,
)
from repro.core.executors import protocol, serialize
from repro.core.executors import shm as shmseg
from repro.core.executors.worker import _PeerNet

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle

    cloudpickle.register_pickle_by_value(sys.modules[__name__])

needs_cloudpickle = pytest.mark.skipif(
    not serialize.HAVE_CLOUDPICKLE,
    reason="cloudpickle needed to ship test-local payload functions")

needs_dev_shm = pytest.mark.skipif(
    not Path("/dev/shm").is_dir(),
    reason="/dev/shm residue checks need a POSIX shm mount")


# ---------------------------------------------------------------------------
# serializer units: array-leaf splitting
# ---------------------------------------------------------------------------
def test_dumps_arrays_round_trip_is_bit_identical():
    obj = {"m": np.arange(48, dtype=np.float32).reshape(6, 8),
           "nested": [np.array([1, 2, 3], dtype=np.int64),
                      ("txt", {"k": np.float64(2.5)})],
           "plain": b"bytes-leaf"}
    skel, metas, bufs = serialize.dumps_arrays(obj)
    body = b"".join(memoryview(b).cast("B") for b in bufs)
    back = serialize.loads_arrays(skel, metas, body)
    assert back["m"].dtype == np.float32 and back["m"].shape == (6, 8)
    assert back["m"].tobytes() == obj["m"].tobytes()
    assert back["nested"][0].tobytes() == obj["nested"][0].tobytes()
    assert back["nested"][1] == ("txt", {"k": np.float64(2.5)})
    assert back["plain"] == b"bytes-leaf"
    # received leaves are zero-copy views into the body: read-only
    assert not back["m"].flags.writeable


def test_dumps_arrays_declines_payloads_without_array_leaves():
    assert serialize.dumps_arrays({"a": 1, "b": "x"}) is None
    assert serialize.dumps_arrays(None) is None
    # object-dtype arrays still need pickle: they must stay opaque leaves
    obj_arr = np.array([{"k": 1}, "s"], dtype=object)
    assert serialize.dumps_arrays([obj_arr]) is None


def test_copy_local_is_writable_and_never_aliases():
    src = {"m": np.zeros(16, dtype=np.float64), "t": (1, "x")}
    cp = serialize.copy_local(src)
    assert cp["m"] is not src["m"] and cp["t"] == (1, "x")
    cp["m"][0] = 99.0                       # writable copy...
    assert src["m"][0] == 0.0               # ...that never aliases the input


# ---------------------------------------------------------------------------
# wire-layer units: generic raw frames and shm frames between two nets
# ---------------------------------------------------------------------------
def test_peer_net_ships_generic_raw_frames():
    a, b = _PeerNet("wa", token="t"), _PeerNet("wb", token="t")
    a.start("127.0.0.1")
    b.start("127.0.0.1")
    obj = {"m": np.arange(1 << 16, dtype=np.int32), "meta": ["x", 7]}
    skel, metas, bufs = serialize.dumps_arrays(obj)
    assert a.send_kind("wb", b.data_addr, protocol.PEER_DATA_GEN, bufs=bufs,
                       skel=skel, arrs=metas, uid=1, attempt=0, seq=0, part=0)
    frame = b.take((1, 0, 0, 0), timeout=10)
    assert frame["nbytes"] == obj["m"].nbytes
    back = serialize.loads_arrays(frame["skel"], frame["arrs"],
                                  frame["payload"])
    assert back["meta"] == ["x", 7]
    assert back["m"].tobytes() == obj["m"].tobytes()


@needs_dev_shm
def test_shm_segment_write_read_unlink_sweep():
    name = shmseg.segment_name("tok12345", "w0")
    assert name.startswith("repro_tok12345_w0_")
    assert shmseg.write(name, [b"ab", b"cd"]) == 4   # multi-buffer body
    assert shmseg.read(name) == b"abcd"
    assert shmseg.unlink(name) is True
    assert shmseg.unlink(name) is False     # idempotent
    # sweep by prefix removes only matching residue
    n1 = shmseg.segment_name("tok12345", "w1")
    n2 = shmseg.segment_name("OTHERtok", "w1")
    shmseg.write(n1, [b"x"])
    shmseg.write(n2, [b"x"])
    assert shmseg.sweep("repro_tok12345_") == 1
    assert not (Path("/dev/shm") / n1).exists()
    assert (Path("/dev/shm") / n2).exists()
    shmseg.unlink(n2)


@needs_dev_shm
def test_peer_net_shm_frame_handoff_and_consume():
    a, b = _PeerNet("wa", token="t"), _PeerNet("wb", token="t")
    a.start("127.0.0.1")
    b.start("127.0.0.1")
    body = b"q" * 4096
    name = shmseg.segment_name("t", "wa")
    shmseg.write(name, [body])
    assert a.send_kind("wb", b.data_addr, protocol.PEER_DATA_SHM, shm=name,
                       nbytes=len(body), skel=None, arrs=None,
                       uid=2, attempt=0, seq=0, part=0)
    frame = b.take((2, 0, 0, 0), timeout=10)
    # the receiving net consumed the segment EAGERLY: the parked frame
    # carries the body and the /dev/shm entry is already gone
    assert frame["payload"] == body and "shm" not in frame
    assert not (Path("/dev/shm") / name).exists()


@needs_dev_shm
def test_purge_unlinks_parked_shm_frames():
    """A parked shm frame whose attempt ends unconsumed must not leak its
    segment: purge owns the cleanup for unclaimable mail."""
    net = _PeerNet("w", token="t")
    name = shmseg.segment_name("t", "w")
    shmseg.write(name, [b"\x00" * 32])
    net.put((5, 0, 0, 1), {"shm": name, "nbytes": 32})
    net.purge(5, 0)
    assert not (Path("/dev/shm") / name).exists()
    # ...and a frame landing AFTER the purge (tombstoned) is reclaimed too
    late = shmseg.segment_name("t", "w")
    shmseg.write(late, [b"\x00" * 32])
    net.put((5, 0, 1, 1), {"shm": late, "nbytes": 32})
    assert not net._mail
    assert not (Path("/dev/shm") / late).exists()


@needs_dev_shm
def test_purge_failed_reclaims_sent_segments():
    """An aborted attempt's receivers raise without consuming, so the
    SENDER's purge(failed=True) must reclaim its ledgered segments; a clean
    finish leaves them to the receivers."""
    net = _PeerNet("w", token="t")
    kept = shmseg.segment_name("t", "w")
    gone = shmseg.segment_name("t", "w")
    shmseg.write(kept, [b"\x00" * 16])
    shmseg.write(gone, [b"\x00" * 16])
    net.record_segment(1, 0, kept)
    net.record_segment(2, 0, gone)
    net.purge(1, 0, failed=False)           # clean: receivers own cleanup
    net.purge(2, 0, failed=True)            # aborted: sender reclaims
    assert (Path("/dev/shm") / kept).exists()
    assert not (Path("/dev/shm") / gone).exists()
    shmseg.unlink(kept)


# ---------------------------------------------------------------------------
# payloads shipped to workers (module-level, pickled by value)
# ---------------------------------------------------------------------------
_ROWS = 32 << 10      # 32k float64 = 256 KiB, well above the 1 KiB threshold


def _array_gather(comm, n_coll=2, rows=_ROWS):
    """Each part allgathers a mixed container whose big leaf is an array;
    verifies content and ordering, reports the transport counters."""
    payload = {"m": np.full((rows,), float(comm.part), dtype=np.float64),
               "tag": ("part", comm.part)}
    for _ in range(n_coll):
        vals = comm.allgather(payload)
        assert len(vals) == comm.n_parts
        for j, v in enumerate(vals):
            assert v["tag"] == ("part", j)
            assert v["m"].dtype == np.float64 and (v["m"] == float(j)).all()
    comm.barrier()
    return {"p2p_bytes": comm.p2p_bytes, "raw": comm.raw_coll_bytes,
            "shm": comm.shm_bytes, "ring": comm.ring_steps,
            "fallbacks": comm.p2p_fallbacks, "hub_calls": comm.hub_calls,
            "n_parts": comm.n_parts}


def _digest_gather(comm, rows=_ROWS):
    """Deterministic digest of a gather + a wide bcast — the bit-identical
    probe compared across every tier configuration."""
    import hashlib
    payload = {"m": np.arange(rows, dtype=np.int64) * (comm.part + 1),
               "mix": [comm.part, "s", {"k": 1.5}, b"\x00\x80"]}
    vals = comm.allgather(payload)
    h = hashlib.sha256()
    for v in vals:
        h.update(np.ascontiguousarray(v["m"]).tobytes())
        h.update(repr(v["mix"]).encode())
    r = comm.bcast(np.arange(rows, dtype=np.float32) + 7.0,
                   root=comm.n_parts - 1)
    h.update(np.ascontiguousarray(r).tobytes())
    return h.hexdigest()


def _slow_gather(comm, n_coll=60, rows=_ROWS):
    for _ in range(n_coll):
        vals = comm.allgather(np.full((rows,), float(comm.part)))
        assert (vals[-1] == float(comm.n_parts - 1)).all()
        time.sleep(0.02)
    return {"ring": comm.ring_steps, "shm": comm.shm_bytes,
            "fallbacks": comm.p2p_fallbacks}


def _wide_bcast(comm, rows=_ROWS):
    """Two large bcasts from different roots; non-root contributions must
    be control-only (zero hub relay) with the payload fanned out by the
    root on the peer plane."""
    for root in (0, comm.n_parts - 1):
        m = comm.bcast(np.full((rows,), float(root)) if comm.part == root
                       else None, root=root)
        assert m.dtype == np.float64 and (m == float(root)).all()
    return {"p2p_bytes": comm.p2p_bytes, "hub_calls": comm.hub_calls,
            "shm": comm.shm_bytes, "fallbacks": comm.p2p_fallbacks}


def _residue(ex) -> list:
    """Live /dev/shm segments belonging to this pilot (by token prefix)."""
    root = Path("/dev/shm")
    if not root.is_dir() or not ex._token:
        return []
    return sorted(p.name for p in root.glob(f"repro_{ex._token[:8]}_*"))


def _wait_no_residue(ex, timeout=6.0):
    deadline = time.monotonic() + timeout
    left = _residue(ex)
    while left and time.monotonic() < deadline:
        time.sleep(0.1)              # worker-side purge may still be running
        left = _residue(ex)
    return left


def _exec(**kw):
    kw.setdefault("devices_per_worker", 1)
    kw.setdefault("build_comm", False)
    kw.setdefault("heartbeat_interval", 0.2)
    kw.setdefault("tick", 0.02)
    return ProcessExecutor(**kw)


def _run_one(ex, fn, ranks, timeout=120, **kwargs):
    sess = SchedulerSession(ex, ex.resource_manager(), tick=0.02)
    rep = sess.run([TaskDescription(name=fn.__name__, ranks=ranks, fn=fn,
                                    kwargs=kwargs, tags={"pipeline": "p"})],
                   timeout=timeout)
    task = rep.tasks[0]
    assert task.state == TaskState.DONE, task.error
    return rep, task


# ---------------------------------------------------------------------------
# end-to-end (subprocess-spawning)
# ---------------------------------------------------------------------------
@needs_cloudpickle
@pytest.mark.integration
def test_generic_allgather_ships_raw_frames():
    """Array-leaf payloads must move as zero-copy raw frames, not pickle:
    raw_coll_bytes covers (at least) the array bodies, end to end through
    PART_DONE accounting up to the executor totals."""
    with _exec(n_workers=2, shm=False) as ex:
        rep, task = _run_one(ex, _array_gather, ranks=2, n_coll=2)
        stats = task.result
        body = _ROWS * 8
        assert stats["raw"] >= 2 * body          # 2 colls x 1 peer each
        assert stats["shm"] == 0 and stats["fallbacks"] == 0
        assert ex.raw_coll_bytes == task.raw_coll_bytes > 0
        assert ex.shm_bytes == 0
        # the barrier token stays pickled-inline: raw never covers it
        assert stats["p2p_bytes"] >= stats["raw"]


@needs_cloudpickle
@needs_dev_shm
@pytest.mark.integration
def test_shm_tier_carries_same_host_payloads_and_leaves_no_residue(
        monkeypatch):
    """Same-host peers must hand payload bodies through shared memory
    (shm_bytes > 0, a subset of p2p_bytes) and leave /dev/shm clean after
    the run and after shutdown."""
    monkeypatch.setenv("REPRO_SHM", "1")         # pin: CI runs both halves
    with _exec(n_workers=2) as ex:
        assert ex.shm is True                    # env knob resolution
        rep, task = _run_one(ex, _array_gather, ranks=2, n_coll=3)
        stats = task.result
        body = _ROWS * 8
        assert stats["shm"] >= 3 * body
        assert stats["fallbacks"] == 0
        # result carries ONE part's counters; executor totals sum both parts
        assert task.shm_bytes == ex.shm_bytes == 2 * stats["shm"]
        assert ex.shm_bytes <= ex.p2p_bytes
        assert _wait_no_residue(ex) == []        # receivers consumed all
    assert _residue(ex) == []                    # shutdown sweep safety net


@needs_cloudpickle
@pytest.mark.integration
def test_ring_allgather_on_wide_task():
    """4 parts >= RING_MIN_PARTS: blocks move around the ring (P-1 forwards
    per part per collective) instead of direct all-to-all, with correct,
    part-ordered results."""
    with _exec(n_workers=4) as ex:
        rep, task = _run_one(ex, _array_gather, ranks=4, n_coll=2)
        stats = task.result
        assert stats["n_parts"] == 4
        # every part forwarded P-1 = 3 blocks per collective (2 of them)
        assert task.ring_steps == 4 * 3 * 2
        assert stats["fallbacks"] == 0
        assert ex.ring_steps == task.ring_steps


@needs_cloudpickle
@pytest.mark.integration
def test_results_bit_identical_across_all_tier_configs():
    """The fallback ladder acceptance: every knob combination — shm off,
    ring off, raw framing off, whole peer plane off — must produce the
    byte-for-byte identical collective results."""
    digests = {}
    configs = {"full": {}, "no_shm": {"shm": False},
               "no_ring": {"ring": False},
               "pickled": {"raw_frames": False, "shm": False},
               "hub_only": {"p2p": False}}
    for name, kw in configs.items():
        with _exec(n_workers=4, **kw) as ex:
            rep, task = _run_one(ex, _digest_gather, ranks=4)
            digests[name] = task.result
            if name == "no_ring":
                assert ex.ring_steps == 0
            if name in ("no_shm", "pickled", "hub_only"):
                assert ex.shm_bytes == 0
            if name in ("pickled", "hub_only"):
                assert ex.raw_coll_bytes == 0
    assert len(set(digests.values())) == 1, digests


@needs_cloudpickle
@pytest.mark.integration
def test_mixed_raw_and_pickled_payloads_in_one_task(monkeypatch):
    """Within one task some collectives have array leaves (raw tier) and
    some do not (pickled tier); REPRO_SHM=0 must also hold as the env
    knob.  _digest_gather mixes both shapes in a single allgather."""
    monkeypatch.setenv("REPRO_SHM", "0")
    with _exec(n_workers=2) as ex:
        assert ex.shm is False                   # env knob resolution
        rep, task = _run_one(ex, _digest_gather, ranks=2)
        assert ex.shm_bytes == 0
        assert ex.raw_coll_bytes > 0             # arrays still went raw
        assert ex.hub_relay_bytes < 1024         # bodies stayed off the hub


@needs_cloudpickle
@pytest.mark.integration
def test_bcast_root_fanout_keeps_hub_control_only():
    """Non-root bcast parts contribute zero-byte control frames: the hub
    must relay NO payload bytes for a wide peer-plane bcast, and every
    receiver still gets the root's array."""
    with _exec(n_workers=3) as ex:
        rep, task = _run_one(ex, _wide_bcast, ranks=3)
        stats = task.result
        assert stats["fallbacks"] == 0
        assert ex.hub_relay_bytes == 0           # placeholders + b"" only
        # only the roots fanned out: 2 bcasts x 2 peers x one body each
        # (executor totals — a single part only sees its own root fanout)
        assert ex.p2p_bytes >= 2 * 2 * _ROWS * 8


@needs_cloudpickle
@pytest.mark.integration
def test_sigkill_mid_ring_recovers_via_retry_with_exclusion():
    """SIGKILL a worker while a wide task streams ring collectives: the
    loss surfaces as one targeted device_failure, and the retry (still
    >= RING_MIN_PARTS survivors: the ring again) completes with exclusion."""
    with _exec(n_workers=5) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="victim", ranks=4, fn=_slow_gather,
                                     max_retries=2, tags={"pipeline": "p"})])
        time.sleep(0.6)              # mid-stream: several colls in flight
        victim = sorted({d.worker for d in
                         next(iter(ex._running.values())).task.devices})[0]
        ex.kill_worker(victim, signal.SIGKILL)
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE, task.error
        fails = rep.events("device_failure")
        assert len(fails) == 1 and fails[0].value == 1.0
        assert task.retries >= 1
        assert any(d.worker == victim for d in task.excluded_devices)
        assert victim not in {d.worker for d in task.devices}
        assert task.result["fallbacks"] == 0     # fresh retry, clean ring
        assert rm.total == 4


@needs_cloudpickle
@needs_dev_shm
@pytest.mark.integration
def test_sigkill_mid_shm_handoff_recovers_and_reclaims_segments(monkeypatch):
    """SIGKILL mid shm-handoff: retry-with-exclusion completes on the
    survivors (their own shm tier again) and NO segment of the pilot
    leaks — survivors purge their aborted attempt, the parent sweeps the
    dead worker's prefix."""
    monkeypatch.setenv("REPRO_SHM", "1")
    with _exec(n_workers=3) as ex:
        rm = ex.resource_manager()
        sess = SchedulerSession(ex, rm, tick=0.02)
        sess.submit([TaskDescription(name="victim", ranks=2, fn=_slow_gather,
                                     max_retries=2, tags={"pipeline": "p"})])
        time.sleep(0.5)
        victim = sorted({d.worker for d in
                         next(iter(ex._running.values())).task.devices})[0]
        ex.kill_worker(victim, signal.SIGKILL)
        rep = sess.drain(timeout=120).close()
        task = rep.tasks[0]
        assert task.state == TaskState.DONE, task.error
        assert task.retries >= 1
        assert any(d.worker == victim for d in task.excluded_devices)
        assert task.result["shm"] > 0            # the retry used shm again
        assert _wait_no_residue(ex) == []        # no leaked segments
    assert _residue(ex) == []


@needs_cloudpickle
@needs_dev_shm
@pytest.mark.integration
def test_retire_worker_leaves_no_shm_residue(monkeypatch):
    """A graceful retire (drain) after shm-heavy traffic must leave
    /dev/shm clean: consumed segments are gone and the retiree's prefix is
    swept on dismissal."""
    monkeypatch.setenv("REPRO_SHM", "1")
    with _exec(n_workers=2) as ex:
        rep, task = _run_one(ex, _array_gather, ranks=2, n_coll=3)
        assert task.result["shm"] > 0
        ex.retire_worker("w1")
        assert _wait_no_residue(ex) == []
    assert _residue(ex) == []


@needs_cloudpickle
@pytest.mark.integration
def test_ring_knob_reverts_to_direct(monkeypatch):
    """REPRO_RING=0 keeps wide tasks on the direct path — zero ring steps,
    same results, raw framing still on."""
    monkeypatch.setenv("REPRO_RING", "0")
    with _exec(n_workers=4) as ex:
        assert ex.ring is False
        rep, task = _run_one(ex, _array_gather, ranks=4, n_coll=2)
        assert task.ring_steps == 0 and ex.ring_steps == 0
        assert task.result["fallbacks"] == 0
        assert ex.raw_coll_bytes > 0
