"""Serving engine: batched greedy generation == full-forward oracle, across
families and mixed prompt lengths."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import get_model
from repro.serve.engine import Request, ServeEngine, greedy_reference


@pytest.mark.parametrize("arch", ["qwen3-8b", "falcon-mamba-7b",
                                  "qwen2-moe-a2.7b", "whisper-medium"])
def test_batched_generation_matches_oracle(arch):
    cfg = dataclasses.replace(reduced(get_config(arch)), n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.key(0), cfg)
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=32)
    reqs = [Request(prompt=np.asarray([5, 7, 9], np.int32), max_new_tokens=4, uid=1),
            Request(prompt=np.asarray([3, 2, 1], np.int32), max_new_tokens=4, uid=2),
            Request(prompt=np.asarray([11, 4], np.int32), max_new_tokens=3, uid=3)]
    out = eng.run_requests(reqs)
    for r in reqs:
        ref = greedy_reference(cfg, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(out[r.uid], ref)


def test_mixed_lengths_grouped():
    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")), n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.key(1), cfg)
    eng = ServeEngine(cfg, params, max_batch=2, max_seq=24)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, L).astype(np.int32),
                    max_new_tokens=3, uid=i)
            for i, L in enumerate([2, 5, 2, 5, 3])]
    out = eng.run_requests(reqs)
    assert set(out) == set(range(5))
    for r in reqs:
        ref = greedy_reference(cfg, params, r.prompt, 3)
        np.testing.assert_array_equal(out[r.uid], ref)
