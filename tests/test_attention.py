"""Attention core invariants: blockwise==full, causal-skip==masked sweep,
GQA grouping, decode path, RoPE shift property."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models.layers import apply_rope, rope_sincos


def _qkv(key, b, s, h, kh, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, h, hd), dtype),
            jax.random.normal(ks[1], (b, s, kh, hd), dtype),
            jax.random.normal(ks[2], (b, s, kh, hd), dtype))


@pytest.mark.parametrize("h,kh", [(4, 4), (8, 2), (6, 1)])
@pytest.mark.parametrize("s", [32, 96, 128])
def test_blockwise_equals_full(h, kh, s):
    q, k, v = _qkv(jax.random.key(0), 2, s, h, kh, 32)
    full = A.attend_full(q, k, v, causal=True)
    blk = A.attend_blockwise(q, k, v, causal=True, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(full),
                               atol=2e-5, rtol=2e-5)


def test_causal_skip_equals_masked():
    q, k, v = _qkv(jax.random.key(1), 1, 128, 4, 2, 16)
    a = A.attend_blockwise(q, k, v, causal=True, q_block=32, kv_block=32,
                           causal_skip=False)
    b = A.attend_blockwise(q, k, v, causal=True, q_block=32, kv_block=32,
                           causal_skip=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_non_causal():
    q, k, v = _qkv(jax.random.key(2), 2, 64, 4, 4, 16)
    full = A.attend_full(q, k, v, causal=False)
    blk = A.attend_blockwise(q, k, v, causal=False, q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(blk), np.asarray(full), atol=2e-5)


def test_gqa_equals_repeated_mha():
    """GQA must equal MHA with K/V repeated per group."""
    q, k, v = _qkv(jax.random.key(3), 1, 24, 8, 2, 16)
    gqa = A.attend_full(q, k, v, causal=True)
    krep = jnp.repeat(k, 4, axis=2)
    vrep = jnp.repeat(v, 4, axis=2)
    mha = A.attend_full(q, krep, vrep, causal=True)
    np.testing.assert_allclose(np.asarray(gqa), np.asarray(mha), atol=1e-5)


def test_decode_matches_last_row():
    b, s, h, kh, hd = 2, 12, 4, 2, 16
    q, k, v = _qkv(jax.random.key(4), b, s, h, kh, hd)
    full = A.attend_full(q, k, v, causal=True)
    # decode: last query vs cache = all keys
    smax = 20
    kc = jnp.zeros((b, smax, kh, hd)).at[:, :s].set(k)
    vc = jnp.zeros((b, smax, kh, hd)).at[:, :s].set(v)
    out = A.attend_decode(q[:, -1:], kc, vc, jnp.full((b,), s, jnp.int32))
    np.testing.assert_allclose(np.asarray(out[:, 0]), np.asarray(full[:, -1]),
                               atol=1e-5)


def test_rope_relative_shift():
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    hd = 32
    q = jax.random.normal(jax.random.key(5), (hd,))
    k = jax.random.normal(jax.random.key(6), (hd,))

    def dot_at(i, j):
        si, ci = rope_sincos(jnp.asarray([i]), hd, 1e4)
        sj, cj = rope_sincos(jnp.asarray([j]), hd, 1e4)
        qr = apply_rope(q[None, None, :], si, ci)[0, 0]
        kr = apply_rope(k[None, None, :], sj, cj)[0, 0]
        return float(jnp.dot(qr, kr))

    assert abs(dot_at(3, 1) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(0, 0) - dot_at(7, 7)) < 1e-4


def test_cache_update_per_batch_positions():
    b, smax, kh, hd = 3, 8, 2, 4
    kc = jnp.zeros((b, smax, kh, hd))
    vc = jnp.zeros((b, smax, kh, hd))
    knew = jnp.ones((b, 1, kh, hd))
    pos = jnp.asarray([0, 3, 7])
    kc2, _ = A.cache_update(kc, vc, knew, knew, pos)
    for i, p in enumerate([0, 3, 7]):
        assert float(kc2[i, p].sum()) == kh * hd
        assert float(kc2[i].sum()) == kh * hd  # only one slot written
