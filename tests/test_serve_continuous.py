"""Continuous batching: token streams bit-identical to the full-forward
oracle under staggered admission and slot reuse; sequence-budget eviction;
the sustained-pressure autoscaler (fake clock + live ThreadExecutor); and
the serve-as-scheduler-tasks driver sharing a session with ETL work."""
import dataclasses
import time

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core import (ResourceManager, SchedulerSession, TaskDescription,
                        TaskState, ThreadExecutor)
from repro.models import get_model
from repro.serve import (AutoscaleConfig, ContinuousEngine, Request,
                         ServeAutoscaler, ServeDriver, greedy_reference)


def _make(arch, seed=0):
    cfg = dataclasses.replace(reduced(get_config(arch)), n_layers=2)
    api = get_model(cfg)
    return cfg, api.init(jax.random.key(seed), cfg)


def _reqs(cfg, spec, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(prompt=rng.integers(0, cfg.vocab_size, L)
                    .astype(np.int32), max_new_tokens=m, uid=i)
            for i, (L, m) in enumerate(spec)]


def _check_oracle(cfg, params, reqs, out):
    for r in reqs:
        ref = greedy_reference(cfg, params, r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(out[r.uid], ref)


@pytest.mark.parametrize("arch", ["qwen3-8b", "falcon-mamba-7b",
                                  "qwen2-moe-a2.7b", "whisper-medium",
                                  "internvl2-1b"])
def test_staggered_admission_matches_oracle(arch):
    """max_batch=2 over 5 mixed-length / mixed-budget requests forces the
    continuous path: requests admitted mid-decode into slots whose
    neighbour is at a different position, and slots reused across requests.
    Every stream must equal the full-forward oracle bit for bit."""
    cfg, params = _make(arch)
    eng = ContinuousEngine(cfg, params, max_batch=2, max_seq=48)
    reqs = _reqs(cfg, [(3, 4), (2, 6), (5, 3), (3, 2), (4, 5)])
    out = eng.run(reqs)
    _check_oracle(cfg, params, reqs, out)
    snap = eng.metrics.snapshot()
    assert snap["serve_admitted"] == 5 and snap["serve_completed"] == 5
    assert snap["serve_slots_active"] == 0 and snap["serve_queue_depth"] == 0


def test_mixed_budgets_and_immediate_completion():
    """Mixed max_new_tokens on one engine: a short request finishing early
    frees its slot for the queue while long neighbours keep decoding, and a
    max_new_tokens=1 request completes at admission without ever taking a
    slot (the prefill logits are the whole generation)."""
    cfg, params = _make("granite-3-8b")
    eng = ContinuousEngine(cfg, params, max_batch=3, max_seq=32)
    reqs = _reqs(cfg, [(2, 8), (5, 1), (3, 2), (2, 5), (4, 1), (3, 7),
                       (2, 3)])
    out = eng.run(reqs)
    assert set(out) == set(range(7))
    _check_oracle(cfg, params, reqs, out)
    assert eng.metrics.get("serve_decode_steps") >= 7   # longest stream
    assert eng.metrics.get("serve_prefill_tokens") == \
        sum(len(r.prompt) for r in reqs)


def test_sequence_budget_eviction():
    """A request whose prefix + prompt + budget overflows max_seq is evicted
    at admission control — never queued, never decoded — and the rest of
    the stream is served normally."""
    cfg, params = _make("granite-3-8b")
    eng = ContinuousEngine(cfg, params, max_batch=2, max_seq=16)
    reqs = _reqs(cfg, [(3, 4), (8, 12), (2, 3)])   # 8+12 > 16: evicted
    out = eng.run(reqs)
    assert eng.evicted == [1] and 1 not in out
    assert eng.metrics.get("serve_evicted") == 1
    _check_oracle(cfg, params, [reqs[0], reqs[2]], out)


def test_autoscaler_policy_fake_clock():
    """Policy unit-test on a fake clock: conditions must SUSTAIN before an
    action fires, a condition flip resets the onset, cooldown separates
    actions, worker bounds gate, and a failing callback is advisory."""
    t = [0.0]
    calls = []
    cfg = AutoscaleConfig(queue_high=3, idle_frac=0.25, sustain_s=1.0,
                          cooldown_s=5.0, min_workers=1, max_workers=2)
    asc = ServeAutoscaler(lambda: calls.append("grow"),
                          lambda: calls.append("retire"),
                          cfg, workers=1, clock=lambda: t[0])
    assert asc.observe(10, 4, 4) is None          # backlog onset
    t[0] = 0.9
    assert asc.observe(0, 0, 4) is None           # flip to idle: reset onset
    t[0] = 1.2
    assert asc.observe(10, 4, 4) is None          # backlog onset again
    t[0] = 1.9
    assert asc.observe(10, 4, 4) is None          # not sustained yet
    t[0] = 2.5
    assert asc.observe(10, 4, 4) == "grow"        # sustained 1.3s >= 1.0
    assert asc.workers == 2 and calls == ["grow"]
    t[0] = 4.0
    assert asc.observe(10, 4, 4) is None          # cooldown + max_workers
    t[0] = 8.0
    assert asc.observe(10, 4, 4) is None          # past cooldown: bound gates
    assert asc.observe(0, 0, 4) is None           # idle onset
    t[0] = 9.5
    assert asc.observe(0, 0, 4) == "retire"       # sustained + past cooldown
    assert asc.workers == 1 and calls == ["grow", "retire"]
    t[0] = 20.0
    assert asc.observe(0, 0, 4) is None           # min_workers gates
    # a raising callback is swallowed and counts nothing
    boom = ServeAutoscaler(lambda: 1 / 0, lambda: 1 / 0,
                           dataclasses.replace(cfg, cooldown_s=0.0),
                           workers=1, clock=lambda: t[0])
    boom.observe(10, 4, 4)
    t[0] = 25.0
    assert boom.observe(10, 4, 4) is None and boom.workers == 1


def test_serve_driver_tasks_bit_identical():
    """The driver serves through scheduler tasks — prefill and decode as
    separately-tagged pipelines sharing the session with an ETL pipeline —
    and the streams still match the oracle.  Serve telemetry lands in the
    session's trace under the driver's worker id."""
    cfg, params = _make("qwen3-8b")
    eng = ContinuousEngine(cfg, params, max_batch=2, max_seq=32)
    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["d0", "d1", "d2"]), tick=0.01)
    sess.submit([TaskDescription(name=f"etl{i}", ranks=1,
                                 fn=lambda c: sum(range(1000)),
                                 tags={"pipeline": "etl"})
                 for i in range(3)])
    driver = ServeDriver(eng, sess, telemetry_interval=0.0)
    reqs = _reqs(cfg, [(3, 4), (2, 6), (4, 3), (3, 1), (2, 2)])
    out = driver.run(reqs, timeout=300)
    _check_oracle(cfg, params, reqs, out)
    rep = sess.drain(timeout=60).close()
    assert all(t.state is TaskState.DONE for t in rep.tasks)
    pipes = {e.pipeline for e in rep.trace if e.kind == "dispatch"}
    assert {"serve-prefill", "serve-decode", "etl"} <= pipes
    tel = [e.data for e in rep.trace if e.kind == "telemetry"
           and e.data.get("worker") == "serve-driver"]
    assert tel and "serve_slot_occupancy" in tel[-1]
    assert tel[-1]["serve_completed"] == len(reqs)


def test_autoscale_integration_grow_then_retire():
    """Live elastic loop on ThreadExecutor: a sustained backlog (8 requests
    vs 2 slots) makes the autoscaler grow the pool (``inject_grow`` ->
    ``grow`` TraceEvent absorbed by the core), and a sustained idle tail
    after the drain retires the added device (``retire`` TraceEvent)."""
    cfg, params = _make("granite-3-8b")
    eng = ContinuousEngine(cfg, params, max_batch=2, max_seq=32)
    ex = ThreadExecutor(build_comm=False, tick=0.01)
    sess = SchedulerSession(ex, ResourceManager(["d0", "d1"]), tick=0.01)
    grown = []

    def grow():
        h = f"g{len(grown)}"
        grown.append(h)
        ex.inject_grow([h])

    asc = ServeAutoscaler(grow, lambda: ex.inject_retire([grown.pop()]),
                          AutoscaleConfig(queue_high=2, idle_frac=0.6,
                                          sustain_s=0.005, cooldown_s=0.01,
                                          min_workers=1, max_workers=2),
                          workers=1)
    driver = ServeDriver(eng, sess, autoscaler=asc, telemetry_interval=0.0)
    out = driver.run(_reqs(cfg, [(3, 8)] * 8), timeout=300)
    assert len(out) == 8
    assert any(kind == "grow" for _, kind in asc.actions)
    # idle tail: the queue stays empty and the slots stay free, so the
    # policy (observed here directly, as a router's idle loop would) fires
    # the retire once the condition sustains past the cooldown
    deadline = time.time() + 10
    while not any(kind == "retire" for _, kind in asc.actions):
        assert time.time() < deadline, "retire never fired"
        asc.observe(0, 0, eng.max_batch)
        time.sleep(0.002)
    # one more scheduler step absorbs the queued retire event
    sess.submit([TaskDescription(name="post", ranks=1, fn=lambda c: 0,
                                 tags={"pipeline": "etl"})])
    rep = sess.drain(timeout=60).close()
    kinds = {e.kind for e in rep.trace}
    assert "grow" in kinds and "retire" in kinds
    assert sess.rm.total == 2          # grew to 3, retired back to 2
