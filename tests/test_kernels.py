"""Pallas kernel validation: shape/dtype sweeps, interpret=True vs the
pure-jnp ref.py oracle of each kernel (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.kernels.bitonic_sort.ops import bitonic_sort
from repro.kernels.bitonic_sort.ref import sort_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.radix_partition.ops import radix_partition
from repro.kernels.radix_partition.ref import destinations_ref
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_ref


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("b,s,h,kh,hd", [
    (1, 128, 4, 4, 32),      # MHA
    pytest.param(2, 256, 8, 2, 64, marks=pytest.mark.slow),   # GQA 4x
    pytest.param(1, 130, 8, 8, 32, marks=pytest.mark.slow),   # unaligned seq
    pytest.param(2, 384, 6, 3, 128, marks=pytest.mark.slow),  # large head_dim
])
@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
def test_flash_attention_sweep(b, s, h, kh, hd, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), dtype)
    k = jax.random.normal(ks[1], (b, s, kh, hd), dtype)
    v = jax.random.normal(ks[2], (b, s, kh, hd), dtype)
    out = flash_attention(q, k, v, causal=True, q_block=128, kv_block=128,
                          interpret=True)
    ref = jnp.moveaxis(attention_ref(jnp.moveaxis(q, 2, 1),
                                     jnp.moveaxis(k, 2, 1),
                                     jnp.moveaxis(v, 2, 1), causal=True), 1, 2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# ssm scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,D,N,dblk,chunk", [
    (1, 64, 32, 8, 16, 16),
    pytest.param(2, 128, 64, 16, 32, 64, marks=pytest.mark.slow),
    pytest.param(1, 96, 48, 4, 48, 32, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("dtype", [
    jnp.float32,
    pytest.param(jnp.bfloat16, marks=pytest.mark.slow),
])
def test_ssm_scan_sweep(B, S, D, N, dblk, chunk, dtype):
    ks = jax.random.split(jax.random.key(1), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, D))).astype(dtype)
    A = -jnp.exp(jax.random.normal(ks[1], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[2], (B, S, N), dtype)
    Cm = jax.random.normal(ks[3], (B, S, N), dtype)
    x = jax.random.normal(ks[4], (B, S, D), dtype)
    y = ssm_scan(dt, A, Bm, Cm, x, d_block=dblk, chunk=chunk, interpret=True)
    yr = ssm_scan_ref(dt, A, Bm, Cm, x)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=tol, rtol=tol)


# ---------------------------------------------------------------------------
# bitonic sort
# ---------------------------------------------------------------------------
@pytest.mark.slow  # interpret-mode bitonic passes are minutes-each on CPU
@pytest.mark.parametrize("rows,n", [(1, 64), (4, 100), (2, 256), (3, 17)])
@pytest.mark.parametrize("dtype", [jnp.int32, jnp.float32])
def test_bitonic_sort_sweep(rows, n, dtype):
    if dtype == jnp.int32:
        keys = jax.random.randint(jax.random.key(2), (rows, n), -500, 500, dtype)
    else:
        keys = jax.random.normal(jax.random.key(2), (rows, n), dtype)
    payload = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (rows, n))
    ks, ps = bitonic_sort(keys, payload, interpret=True)
    kr, _ = sort_ref(keys, payload)
    np.testing.assert_array_equal(np.asarray(ks), np.asarray(kr))
    # payload is a valid permutation applying the same order
    regathered = np.take_along_axis(np.asarray(keys), np.asarray(ps), -1)
    np.testing.assert_array_equal(regathered, np.asarray(kr))


# ---------------------------------------------------------------------------
# radix partition
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("n,buckets,block", [
    pytest.param(256, 4, 64, marks=pytest.mark.slow),
    pytest.param(1000, 16, 256, marks=pytest.mark.slow),
    (64, 8, 64),
    pytest.param(513, 7, 128, marks=pytest.mark.slow),
])
def test_radix_partition_sweep(n, buckets, block):
    b = jax.random.randint(jax.random.key(3), (n,), 0, buckets, jnp.int32)
    dest, hist = radix_partition(b, buckets, block=block, interpret=True)
    dref, href = destinations_ref(b, buckets)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(href))
    np.testing.assert_array_equal(np.asarray(dest), np.asarray(dref))
    # dest is a permutation of [0, n)
    assert sorted(np.asarray(dest).tolist()) == list(range(n))


def test_radix_partition_is_stable():
    b = jnp.asarray([1, 0, 1, 0, 1], jnp.int32)
    dest, hist = radix_partition(b, 2, block=64, interpret=True)
    # bucket 0 rows (idx 1,3) keep order; bucket 1 rows (0,2,4) keep order
    d = np.asarray(dest)
    assert d[1] < d[3]
    assert d[0] < d[2] < d[4]


def test_radix_partition_single_bucket_is_identity():
    """Degenerate 1-bucket case: the partition is the identity and the
    pad-correction path must not mangle the histogram (padding targets
    bucket n_buckets - 1 == 0, the same bucket every real row occupies)."""
    for n in (1, 5, 64, 100, 129):
        b = jnp.zeros((n,), jnp.int32)
        dest, hist = radix_partition(b, 1, block=64, interpret=True)
        np.testing.assert_array_equal(np.asarray(dest), np.arange(n))
        np.testing.assert_array_equal(np.asarray(hist), [n])


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=600),
       st.integers(min_value=1, max_value=9),
       st.sampled_from([16, 64, 128, 256]),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_radix_partition_matches_ref_property(n, buckets, block, seed):
    """Property: dest/hist match ref.py bit-for-bit for arbitrary sizes,
    including n < block, n % block != 0, and the 1-bucket degenerate case
    (the pad-correction regression surface)."""
    b = jax.random.randint(jax.random.key(seed), (n,), 0, buckets, jnp.int32)
    dest, hist = radix_partition(b, buckets, block=block, interpret=True)
    dref, href = destinations_ref(b, buckets)
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(href))
    np.testing.assert_array_equal(np.asarray(dest), np.asarray(dref))
