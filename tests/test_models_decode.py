"""Decode-path consistency: prefill + stepwise decode must reproduce the
full-forward logits for EVERY architecture family (the strongest correctness
invariant of the serving stack)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs, reduced
from repro.models import get_model, make_concrete_batch, train_batch_shapes

RNG = np.random.default_rng(1)
B, S, SMAX = 2, 8, 16


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_forward(arch):
    cfg = reduced(get_config(arch))
    api = get_model(cfg)
    params = api.init(jax.random.key(0), cfg)
    batch = make_concrete_batch(train_batch_shapes(cfg, B, S), RNG,
                                cfg.vocab_size)
    fwd = api.forward(params, cfg, batch)
    prefix = batch.get("prefix_embeds")
    P = prefix.shape[1] if prefix is not None else 0

    t0 = S - 2
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :t0]
    pre.pop("labels", None)
    cache, logits0 = api.prefill(params, cfg, pre, SMAX)
    np.testing.assert_allclose(np.asarray(logits0),
                               np.asarray(fwd[:, P + t0 - 1]), atol=5e-4)
    for t in range(t0, S):
        db = {"tokens": batch["tokens"][:, t:t + 1],
              "positions": jnp.full((B,), P + t, jnp.int32)}
        logits, cache = api.decode_step(params, cfg, db, cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(fwd[:, P + t]), atol=5e-4)
