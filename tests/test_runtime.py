"""Scheduler / pilot runtime invariants — the paper-core logic, including
hypothesis property tests over random task mixes."""
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.core import (
    BATCH, HETEROGENEOUS, PilotDescription, PilotManager, ResourceManager,
    SimOptions, TaskDescription, TaskState, simulate,
)


def _mk_tasks(sizes, dur=10.0, pipeline=None, name="t"):
    return [TaskDescription(
        name=f"{name}{i}", ranks=r, fn=None,
        duration_model=(lambda rr, d=dur: d),
        tags={"pipeline": pipeline or name}) for i, r in enumerate(sizes)]


def test_all_tasks_complete():
    tasks = _mk_tasks([4, 8, 2, 16, 4])
    rep = simulate(tasks, 16, SimOptions(noise=0.0))
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    assert rep.makespan > 0


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(1, 16), min_size=1, max_size=20),
       st.integers(16, 64))
def test_property_completion_and_capacity(sizes, ndev):
    """Every feasible task completes; resource accounting never goes
    negative (simulate would crash/deadlock otherwise)."""
    tasks = _mk_tasks(sizes)
    rep = simulate(tasks, ndev, SimOptions(noise=0.0))
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    # serial lower bound: total work / devices <= makespan (+overheads)
    work = sum(s * 10.0 for s in sizes)
    assert rep.makespan >= work / ndev - 1e-6


def test_heterogeneous_beats_batch_on_imbalanced_mix():
    """The paper's §4.3 effect: a shared pool backfills released resources;
    static partitions cannot."""
    join = _mk_tasks([8, 8, 8, 8], dur=10.0, pipeline="join", name="join")
    sort = _mk_tasks([8, 8, 8, 8, 8, 8, 8, 8], dur=4.0, pipeline="sort",
                     name="sort")
    het = simulate(join + sort, 16, SimOptions(policy=HETEROGENEOUS, noise=0.0))
    bat = simulate(join + sort, 16, SimOptions(policy=BATCH, noise=0.0))
    assert het.makespan < bat.makespan


def test_overhead_is_constant_per_task():
    tasks = _mk_tasks([4, 4])
    opts = SimOptions(noise=0.0, overhead_model=lambda r: 2.5)
    rep = simulate(tasks, 8, opts)
    assert rep.overhead_total == pytest.approx(5.0)
    assert all(t.comm_build_time == 2.5 for t in rep.tasks)


def test_retry_on_failure():
    tasks = _mk_tasks([4])
    # failure_prob 1 would always fail; use scripted seed with prob 0.5
    opts = SimOptions(noise=0.0, failure_prob=0.4, seed=3)
    rep = simulate(tasks * 1, 8, opts)
    t = rep.tasks[0]
    assert t.state in (TaskState.DONE, TaskState.FAILED)
    if t.retries:
        assert rep.n_retries >= 1


def test_exhausted_retries_fail():
    descs = [TaskDescription(name="f", ranks=2, fn=None, max_retries=1,
                             duration_model=lambda r: 5.0,
                             tags={"pipeline": "p"})]
    rep = simulate(descs, 4, SimOptions(noise=0.0, failure_prob=1.0))
    assert rep.tasks[0].state == TaskState.FAILED
    assert rep.tasks[0].retries == 2  # initial + 1 retry counted as attempts


def test_straggler_speculation_improves_makespan():
    descs = _mk_tasks([2] * 12, dur=10.0)
    slow = SimOptions(noise=0.0, straggler_prob=0.2, straggler_slowdown=10.0,
                      seed=5)
    spec = SimOptions(noise=0.0, straggler_prob=0.2, straggler_slowdown=10.0,
                      seed=5, speculative_factor=1.5)
    r_slow = simulate(descs, 8, slow)
    r_spec = simulate(_mk_tasks([2] * 12, dur=10.0), 8, spec)
    assert all(t.state == TaskState.DONE for t in r_spec.tasks
               if t.speculative_of is None)
    assert r_spec.makespan <= r_slow.makespan
    if r_spec.n_speculative:
        assert r_spec.makespan < r_slow.makespan


def test_device_failure_shrinks_pool_but_completes():
    descs = _mk_tasks([4] * 6, dur=10.0)
    rep = simulate(descs, 16, SimOptions(noise=0.0,
                                         device_failures=[(5.0, 8)]))
    assert all(t.state == TaskState.DONE for t in rep.tasks)


def test_determinism():
    descs = _mk_tasks([3, 5, 2, 8], dur=7.0)
    a = simulate(descs, 8, SimOptions(seed=11))
    b = simulate(_mk_tasks([3, 5, 2, 8], dur=7.0), 8, SimOptions(seed=11))
    assert a.makespan == b.makespan


def test_resource_manager_allocate_release():
    rm = ResourceManager(list(range(8)))
    got = rm.allocate(5)
    assert rm.n_free == 3
    rm.release(got)
    assert rm.n_free == 8
    rm.fail_devices([0, 1])
    assert rm.total == 6
    with pytest.raises(Exception):
        rm.allocate(7)


def test_resource_manager_double_release_is_idempotent():
    """Releasing the same device twice must not duplicate it in the free
    list — a duplicated handle could satisfy two concurrent allocations
    with one physical device."""
    rm = ResourceManager(list(range(4)))
    got = rm.allocate(2)
    rm.release(got)
    rm.release(got)               # double release (e.g. retry + reaper race)
    assert rm.n_free == 4
    a = rm.allocate(4)
    assert len(set(a)) == 4       # every handle issued exactly once
    with pytest.raises(Exception):
        rm.allocate(1)


def test_pilot_carves_from_global_pool():
    pm = PilotManager(devices=list(range(16)))
    p = pm.submit_pilot(PilotDescription(n_devices=10))
    assert p.resource_manager.total == 10
    assert pm.global_rm.n_free == 6
