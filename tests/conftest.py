"""Shared fixtures.  NOTE: XLA_FLAGS / device-count tricks are NEVER set here
(per spec): smoke tests and benches see 1 device; multi-device integration
tests spawn subprocesses via tests/_subproc.py."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
