"""Unified scheduler core: the SAME dispatch/retry/spec-exec code must drive
both the virtual clock (VirtualClockExecutor) and real threads
(ThreadExecutor), and DAG stages must be released continuously — the moment
their own deps complete — rather than in waves with barriers."""
import inspect
import sys
import time

import pytest

from repro.core import (
    BATCH, InsufficientResources, Pipeline, ProcessExecutor,
    ResourceManager, SchedulerSession, SimOptions, Task, TaskDescription,
    TaskState, ThreadExecutor, VirtualClockExecutor, interleave_by_pipeline,
    run_pipelines, simulate,
)
from repro.core.executors import serialize

if serialize.HAVE_CLOUDPICKLE:
    import cloudpickle
    cloudpickle.register_pickle_by_value(sys.modules[__name__])


def _sim_descs(specs):
    return [TaskDescription(name=n, ranks=1, fn=None,
                            duration_model=(lambda r, d=dur: d),
                            tags={"pipeline": pipe})
            for n, pipe, dur in specs]


def _live_descs(specs, sleep_scale=0.02):
    def mk(dur):
        return lambda comm: time.sleep(dur * sleep_scale) or dur
    return [TaskDescription(name=n, ranks=1, fn=mk(dur),
                            tags={"pipeline": pipe})
            for n, pipe, dur in specs]


def _key_trace(report, kinds=("submit", "dispatch", "done")):
    return [(e.kind, e.task) for e in report.trace if e.kind in kinds]


def test_dispatch_order_identical_across_executors():
    """A deterministic workload serialized on a single device must produce
    the same submit/dispatch/done event order under the virtual clock and
    under real threads — one scheduler implementation, two executors."""
    specs = [("p0", "p", 3.0), ("p1", "p", 1.0),
             ("q0", "q", 2.0), ("q1", "q", 4.0)]

    sim = SchedulerSession(
        VirtualClockExecutor(SimOptions(noise=0.0)),
        ResourceManager([0]))
    sim_rep = sim.run(_sim_descs(specs))

    live = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01),
                            ResourceManager(["dev0"]))
    live_rep = live.run(_live_descs(specs), timeout=60)

    assert all(t.state == TaskState.DONE for t in sim_rep.tasks)
    assert all(t.state == TaskState.DONE for t in live_rep.tasks)
    assert _key_trace(sim_rep) == _key_trace(live_rep)
    dispatch_order = [e.task for e in sim_rep.trace if e.kind == "dispatch"]
    assert dispatch_order == ["p0", "p1", "q0", "q1"]


def test_continuous_release_sim():
    """A dependent stage must start the moment its OWN dep completes, while
    an unrelated sibling stage from another pipeline is still running.
    Under the old wave-barrier run_pipelines, stage b could not start until
    the whole {a, c} wave drained (t=10); continuously released it starts at
    t=1."""
    P = Pipeline("P")
    P.add("a", 1, duration_model=lambda r: 1.0)
    P.add("b", 1, duration_model=lambda r: 1.0, deps=["a"])
    Q = Pipeline("Q")
    Q.add("c", 1, duration_model=lambda r: 10.0)

    rm = ResourceManager(list(range(2)))
    ex = VirtualClockExecutor(SimOptions(noise=0.0,
                                         overhead_model=lambda r: 0.0))
    _, rep = run_pipelines([P, Q], rm, executor=ex, timeout=1e9)
    by = {t.desc.name: t for t in rep.tasks}
    assert by["P.b"].start_time == pytest.approx(1.0)
    assert by["Q.c"].end_time == pytest.approx(10.0)
    # the defining assertion: b ran while the unrelated sibling c was running
    assert by["P.b"].start_time < by["Q.c"].end_time
    assert rep.makespan == pytest.approx(10.0)   # wave barrier would give 11


def test_continuous_release_live():
    """Same property on the thread executor with real concurrency."""
    P = Pipeline("P")
    P.add("a", 1, fn=lambda c: time.sleep(0.05) or "a")
    P.add("b", 1, fn=lambda c, a: time.sleep(0.05) or a + "b", deps=["a"])
    Q = Pipeline("Q")
    Q.add("c", 1, fn=lambda c: time.sleep(0.8) or "c")

    rm = ResourceManager(["d0", "d1"])
    results, rep = run_pipelines([P, Q], rm,
                                 executor=ThreadExecutor(build_comm=False,
                                                         tick=0.01),
                                 timeout=60)
    assert results[("P", "b")] == "ab"
    by = {t.desc.name: t for t in rep.tasks}
    assert by["P.b"].start_time < by["Q.c"].end_time
    assert by["P.b"].end_time < by["Q.c"].end_time


def test_batch_policy_insufficient_partition_raises():
    """3 pipelines over 2 devices -> 0 devices per static partition: must
    raise instead of spinning until timeout with undispatchable tasks."""
    descs = [TaskDescription(name=f"t{i}", ranks=1, fn=None,
                             duration_model=lambda r: 1.0,
                             tags={"pipeline": f"pipe{i}"}) for i in range(3)]
    with pytest.raises(InsufficientResources):
        simulate(descs, 2, SimOptions(policy=BATCH, noise=0.0))


def test_simulate_default_options_not_shared():
    """simulate()'s options default must not be a mutable shared instance."""
    assert inspect.signature(simulate).parameters["opts"].default is None
    descs = lambda: [TaskDescription(  # noqa: E731
        name="t", ranks=1, fn=None, duration_model=lambda r: 1.0,
        tags={"pipeline": "p"})]
    a = simulate(descs(), 2)
    b = simulate(descs(), 2)
    assert a.makespan == b.makespan


def test_live_retry_excludes_failed_device():
    """Live mode gains retry-with-device-exclusion from the unified core:
    after an attempt fails on a device, the retry prefers a different one."""
    seen = []

    def flaky(comm):
        dev = comm.devices[0]
        seen.append(dev)
        if dev == "bad":
            raise RuntimeError("device is bad")
        return "ok"

    rm = ResourceManager(["bad", "good"])
    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01), rm)
    rep = sess.run([TaskDescription(name="f", ranks=1, fn=flaky,
                                    max_retries=2,
                                    tags={"pipeline": "p"})], timeout=60)
    task = rep.tasks[0]
    assert task.state == TaskState.DONE
    assert seen[0] == "bad" and seen[-1] == "good"
    assert "bad" in task.excluded_devices


def test_live_speculative_reexecution():
    """Live mode gains straggler detection + spec-exec from the unified
    core: a straggling task is duplicated onto a free device and the run
    finishes at the duplicate's (fast) pace."""
    calls = {"n": 0}

    def work(comm):
        calls["n"] += 1
        # the 4th launch of this task name is the straggler; its speculative
        # duplicate (5th call) runs fast
        time.sleep(2.5 if calls["n"] == 4 else 0.05)
        return calls["n"]

    descs = [TaskDescription(name="w", ranks=1, fn=work,
                             tags={"pipeline": "p"}) for _ in range(4)]
    rm = ResourceManager(["d0", "d1"])
    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.02), rm,
                            speculative_factor=2.0)
    t0 = time.perf_counter()
    rep = sess.run(descs, timeout=60)
    wall = time.perf_counter() - t0
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    assert rep.n_speculative >= 1
    assert wall < 2.0, f"spec-exec should beat the 2.5s straggler, took {wall}"


def test_failed_speculative_duplicate_does_not_kill_primary():
    """If the speculative duplicate itself dies, the straggling primary must
    keep running and deliver the real result (not be cancelled / credited
    with the duplicate's None)."""
    calls = {"n": 0}

    def work(comm):
        calls["n"] += 1
        n = calls["n"]
        if n == 4:                    # the straggler (primary keeps running)
            time.sleep(0.6)
            return "primary"
        if n == 5:                    # its speculative duplicate dies
            raise RuntimeError("dup dies")
        time.sleep(0.05)
        return "fast"

    descs = [TaskDescription(name="w", ranks=1, fn=work,
                             tags={"pipeline": "p"}) for _ in range(4)]
    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.02),
                            ResourceManager(["d0", "d1"]),
                            speculative_factor=2.0)
    rep = sess.run(descs, timeout=60)
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    assert rep.n_speculative >= 1
    # before the dup-failure guard, the dying duplicate cancelled the primary
    # and credited it DONE with result=None
    results = [t.result for t in rep.tasks]
    assert None not in results
    # the straggler finished via its own run or a later (healthy) duplicate
    assert set(results) <= {"fast", "primary"}


def test_elastic_grow_backfills_pending_live():
    """Elastic pool grow: a task too big for the initial pool dispatches
    as soon as devices are added mid-run."""
    rm = ResourceManager(["d0"])
    sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.01), rm)
    sess.submit([TaskDescription(name="small", ranks=1,
                                 fn=lambda c: time.sleep(0.05) or "s",
                                 tags={"pipeline": "p"}),
                 TaskDescription(name="big", ranks=2,
                                 fn=lambda c: "b", tags={"pipeline": "p"})])
    rm.add_devices(["d1"])
    rep = sess.drain(timeout=60).close()
    states = {t.desc.name: t.state for t in rep.tasks}
    assert states == {"small": TaskState.DONE, "big": TaskState.DONE}


def test_batch_close_does_not_release_busy_devices():
    """If run_pipelines tears down after a stage failure while a sibling
    pipeline's task is mid-execution, the busy device must NOT be handed
    back to the parent pool (it would be double-issued)."""
    import threading
    release = threading.Event()
    P = Pipeline("P")
    P.add("bad", 1, fn=lambda c: (_ for _ in ()).throw(RuntimeError("boom")))
    Q = Pipeline("Q")
    Q.add("slow", 1, fn=lambda c: release.wait(5) or "ok")
    rm = ResourceManager(["d0", "d1"])
    with pytest.raises(RuntimeError):
        run_pipelines([P, Q], rm, policy=BATCH,
                      executor=ThreadExecutor(build_comm=False, tick=0.01),
                      timeout=10)
    assert rm.n_free == 1    # only the failed pipeline's partition returns
    release.set()


def test_batch_close_propagates_failed_devices():
    """Devices that died during a BATCH session must stay dead in the parent
    pool after close() (not be resurrected by the partition hand-back)."""
    descs = [TaskDescription(name=f"t{p}", ranks=1, fn=None,
                             duration_model=lambda r: 10.0,
                             tags={"pipeline": p}) for p in ("a", "b")]
    rm = ResourceManager(list(range(4)))
    opts = SimOptions(policy=BATCH, noise=0.0, device_failures=[(1.0, 1)])
    sess = SchedulerSession(VirtualClockExecutor(opts), rm, policy=BATCH)
    rep = sess.run(descs)
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    assert rm.total == 3     # the dead device is gone from the parent too
    assert rm.n_free == 3


def test_event_trace_schema():
    """Every lifecycle step appears in the trace with the documented kinds
    and a per-task submit->dispatch->comm_build->done ordering."""
    descs = [TaskDescription(name=f"t{i}", ranks=2, fn=None,
                             duration_model=lambda r: 5.0,
                             tags={"pipeline": "p"}) for i in range(3)]
    rep = simulate(descs, 4, SimOptions(noise=0.0))
    assert len(rep.events("submit")) == 3
    assert len(rep.events("dispatch")) == 3
    assert len(rep.events("comm_build")) == 3
    assert len(rep.events("done")) == 3
    per_uid = {}
    for e in rep.trace:
        per_uid.setdefault(e.uid, []).append(e.kind)
    for kinds in per_uid.values():
        assert kinds == ["submit", "dispatch", "comm_build", "done"]
    assert rep.overhead_total == pytest.approx(
        sum(e.value for e in rep.events("comm_build")))


_CHAIN_RANKS = [1, 2, 4, 1, 2, 4, 1, 2]    # 4-rank stages span both workers


def _chain_stage(comm, *deps):
    time.sleep(0.02)
    return comm.size


def _chain_pipeline() -> Pipeline:
    """8-stage dependency chain (a DAG whose event order is deterministic on
    every executor), mixing 1/2-rank stages with 4-rank stages that — on a
    2x2 ProcessExecutor — span both worker processes."""
    p = Pipeline("chain")
    prev: list = []
    for i, r in enumerate(_CHAIN_RANKS):
        p.add(f"s{i}", ranks=r, fn=_chain_stage, deps=prev,
              duration_model=lambda rk: 1.0)
        prev = [f"s{i}"]
    return p


@pytest.mark.integration
@pytest.mark.skipif(not serialize.HAVE_CLOUDPICKLE,
                    reason="cloudpickle needed to ship test-local payloads")
def test_trace_skeleton_identical_virtual_thread_process():
    """The SAME 8-task DAG through all three executor backends must produce
    the same ordered (kind, task) trace skeleton — the paper's claim that
    the runtime behaves identically from simulation to multi-node."""
    ex_sim = VirtualClockExecutor(SimOptions(noise=0.0,
                                             overhead_model=lambda r: 0.0))
    _, rep_sim = run_pipelines([_chain_pipeline()],
                               ResourceManager(list(range(4))),
                               executor=ex_sim, timeout=1e9)

    _, rep_thr = run_pipelines([_chain_pipeline()],
                               ResourceManager([f"d{i}" for i in range(4)]),
                               executor=ThreadExecutor(build_comm=False,
                                                       tick=0.01),
                               timeout=120)

    with ProcessExecutor(n_workers=2, devices_per_worker=2,
                         build_comm=False, heartbeat_interval=0.2,
                         tick=0.01) as ex:
        _, rep_proc = run_pipelines([_chain_pipeline()],
                                    ex.resource_manager(),
                                    executor=ex, timeout=120)

    skeletons = [_key_trace(r) for r in (rep_sim, rep_thr, rep_proc)]
    assert len(rep_proc.tasks) == len(_CHAIN_RANKS) >= 8
    assert skeletons[0] == skeletons[1] == skeletons[2]
    # the 4-rank stages really did span both worker processes
    spans = [t for t in rep_proc.tasks if t.desc.ranks == 4]
    assert spans and all(
        len({d.worker for d in t.devices}) == 2 for t in spans)


def _mk_task(name, pipe, priority=0):
    return Task(desc=TaskDescription(name=name, ranks=1, fn=None,
                                     priority=priority,
                                     tags={"pipeline": pipe}))


def test_interleave_by_pipeline_round_robins_fairly():
    """Ordering is load-bearing for fairness: one pipeline's backlog must
    not monopolize the head of the queue.  Round-robin across pipelines,
    stable (submission order) within each pipeline."""
    tasks = [_mk_task("p0", "P"), _mk_task("p1", "P"), _mk_task("p2", "P"),
             _mk_task("q0", "Q"), _mk_task("q1", "Q")]
    out = [t.desc.name for t in interleave_by_pipeline(tasks)]
    assert out == ["p0", "q0", "p1", "q1", "p2"]


def test_interleave_by_pipeline_priority_dominates_round_robin():
    """Priority sorts above the round-robin (stable within a priority
    level), so an urgent task jumps every pipeline's queue."""
    tasks = [_mk_task("p0", "P"), _mk_task("p1", "P"),
             _mk_task("q0", "Q"), _mk_task("q1", "Q", priority=1)]
    out = [t.desc.name for t in interleave_by_pipeline(tasks)]
    assert out == ["q1", "p0", "q0", "p1"]
    # untagged tasks group under the "default" pipeline, not crash
    assert len(interleave_by_pipeline([Task(desc=TaskDescription(
        name="bare", ranks=1, fn=None))])) == 1


def test_interleave_by_pipeline_empty_and_single_group():
    assert interleave_by_pipeline([]) == []
    tasks = [_mk_task(f"t{i}", "solo") for i in range(3)]
    assert [t.desc.name for t in interleave_by_pipeline(tasks)] == \
        ["t0", "t1", "t2"]


def test_wait_any_timeout_not_enforced_on_virtual_clock():
    """Scheduler timeouts are liveness guards against wall-clock hangs; the
    virtual clock drains deterministically, so a tiny ``timeout`` must be
    IGNORED — wait_any advances the clock to the next completion instead of
    returning empty, and drain finishes tasks lasting far past the budget."""
    sess = SchedulerSession(VirtualClockExecutor(SimOptions(noise=0.0)),
                            ResourceManager([0]))
    sess.submit([TaskDescription(name=f"t{i}", ranks=1, fn=None,
                                 duration_model=lambda r: 1000.0,
                                 tags={"pipeline": "p"}) for i in range(2)])
    got = sess.wait_any(timeout=1e-9)
    assert len(got) == 1 and got[0].state == TaskState.DONE
    rep = sess.drain(timeout=1e-9).close()
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    assert rep.makespan > 2000.0     # two serialized 1000s tasks completed


# ---------------------------------------------------------------------------
# work-stealing: elastic BATCH partitions
# ---------------------------------------------------------------------------
def test_work_stealing_strictly_reduces_batch_makespan_sim():
    """Pipeline A is backlogged (6 tasks over its 2-device partition) while
    pipeline B goes idle after 1s.  Static BATCH leaves B's devices idle
    (makespan 6); with work-stealing A leases them and finishes in 4."""
    def descs():
        out = [TaskDescription(name=f"a{i}", ranks=1, fn=None,
                               duration_model=lambda r: 2.0,
                               tags={"pipeline": "A"}) for i in range(6)]
        out.append(TaskDescription(name="b0", ranks=1, fn=None,
                                   duration_model=lambda r: 1.0,
                                   tags={"pipeline": "B"}))
        return out

    import dataclasses
    base = SimOptions(policy=BATCH, noise=0.0, overhead_model=lambda r: 0.0)
    static = simulate(descs(), 4, base)
    steal = simulate(descs(), 4, dataclasses.replace(base,
                                                     work_stealing=True))
    assert all(t.state == TaskState.DONE for t in static.tasks)
    assert all(t.state == TaskState.DONE for t in steal.tasks)
    assert static.makespan == pytest.approx(6.0)
    assert steal.makespan == pytest.approx(4.0)
    assert steal.makespan < static.makespan          # strictly better
    # evidence in the trace: leases taken and handed back, none under static
    assert len(steal.events("steal")) == len(steal.events("return")) == 2
    assert not static.events("steal") and not static.events("return")


_STEAL_SPECS = [("a0", "A", 6.0), ("a1", "A", 3.0), ("a2", "A", 1.0),
                ("b0", "B", 1.0)]
# deterministic steal scenario on 2 devices (one per BATCH partition):
#   t=1 b0 done -> B idle, A backlogged -> a1 leases B's device (steal)
#   t=4 a1 done (return) -> a2 leases it again (steal)
#   t=5 a2 done (return); t=6 a0 done.  No event ties at any scale.


def _steal_session(executor, devices):
    return SchedulerSession(executor, ResourceManager(devices), policy=BATCH,
                            work_stealing=True)


def _steal_key_trace(report):
    return _key_trace(report,
                      kinds=("submit", "dispatch", "done", "steal", "return"))


def test_steal_return_trace_equivalence_sim_thread():
    """The steal/return lifecycle must produce the identical event skeleton
    on the virtual clock and on real threads — stealing lives in the core,
    not in any executor."""
    sim = _steal_session(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0)),
        [0, 1])
    rep_sim = sim.run(_sim_descs(_STEAL_SPECS))

    live = _steal_session(ThreadExecutor(build_comm=False, tick=0.01),
                          ["d0", "d1"])
    rep_thr = live.run(_live_descs(_STEAL_SPECS, sleep_scale=0.2),
                       timeout=60)

    assert all(t.state == TaskState.DONE for t in rep_sim.tasks)
    assert all(t.state == TaskState.DONE for t in rep_thr.tasks)
    assert _steal_key_trace(rep_sim) == _steal_key_trace(rep_thr)
    assert [e.task for e in rep_sim.events("steal")] == ["a1", "a2"]
    assert [e.task for e in rep_sim.events("return")] == ["a1", "a2"]


def test_leased_device_dying_mid_lease_not_counted_as_returned():
    """A leased device that fails while on loan leaves the lender's
    inventory through its device_failure accounting; the thief's ``return``
    event must count only devices actually handed back, or a trace consumer
    balancing steal/return/device_failure double-counts the dead device."""
    sess = _steal_session(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0)),
        [0, 1])
    sess.submit(_sim_descs([("a0", "A", 5.0), ("a1", "A", 3.0),
                            ("b0", "B", 1.0)]))
    done = sess.wait_any()                      # b0 at t=1; a1 then leases
    assert [t.desc.name for t in done] == ["b0"]
    assert [e.kind for e in sess.trace].count("steal") == 1
    sess._pools["B"].fail_devices([1])          # the leased device dies
    rep = sess.drain().close()
    assert all(t.state == TaskState.DONE for t in rep.tasks)
    ret = rep.events("return")
    assert len(ret) == 1 and ret[0].value == 0.0   # nothing came back alive
    assert rep.events("steal")[0].value == 1.0


def _steal_sleep(comm, dur, scale=0.2):
    time.sleep(dur * scale)
    return dur


@pytest.mark.integration
@pytest.mark.skipif(not serialize.HAVE_CLOUDPICKLE,
                    reason="cloudpickle needed to ship test-local payloads")
def test_steal_return_trace_equivalence_includes_process_executor():
    """Same steal scenario through ProcessExecutor: a partition leases a
    device owned by ANOTHER worker process and the skeleton still matches
    the virtual clock's."""
    sim = _steal_session(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0)),
        [0, 1])
    rep_sim = sim.run(_sim_descs(_STEAL_SPECS))

    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, heartbeat_interval=0.2,
                         tick=0.01) as ex:
        sess = _steal_session(ex, list(ex.devices()))
        rep_proc = sess.run(
            [TaskDescription(name=n, ranks=1, fn=_steal_sleep, args=(dur,),
                             tags={"pipeline": pipe})
             for n, pipe, dur in _STEAL_SPECS], timeout=120)

    assert all(t.state == TaskState.DONE for t in rep_proc.tasks)
    assert _steal_key_trace(rep_sim) == _steal_key_trace(rep_proc)
    # the lease really crossed worker processes: a1 ran on B's worker
    by = {t.desc.name: t for t in rep_proc.tasks}
    assert {d.worker for d in by["a0"].devices} != \
        {d.worker for d in by["a1"].devices}


# ---------------------------------------------------------------------------
# elastic grow/retire: one core, every backend carries the evidence
# ---------------------------------------------------------------------------
def _elastic_key_trace(report):
    return _key_trace(report,
                      kinds=("submit", "dispatch", "grow", "retire", "done"))


def test_grow_trace_equivalence_sim_thread():
    """An elastic grow must produce the identical event skeleton on the
    virtual clock (``SimOptions.grow_at`` injection) and on live threads
    (``inject_grow``) — grow handling lives in the core, not in any
    executor: pool add, ``grow`` trace event, re-dispatch of pending work,
    all in one scheduler step."""
    specs = [("a", "p", 1.0), ("wide", "p", 3.0)]
    descs_sim = [TaskDescription(name=n, ranks=r, fn=None,
                                 duration_model=(lambda rk, d=dur: d),
                                 tags={"pipeline": pipe})
                 for (n, pipe, dur), r in zip(specs, (1, 2))]
    sim = SchedulerSession(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0,
                                        grow_at=[(2.0, 2)])),
        ResourceManager([0]))
    rep_sim = sim.run(descs_sim)
    assert sim.rm.total == 3          # invented handles joined the pool

    ex = ThreadExecutor(build_comm=False, tick=0.01)
    rm = ResourceManager(["d0"])
    live = SchedulerSession(ex, rm, tick=0.01)
    live.submit([TaskDescription(name="a", ranks=1,
                                 fn=lambda c: time.sleep(0.05) or "a",
                                 tags={"pipeline": "p"}),
                 TaskDescription(name="wide", ranks=2, fn=lambda c: "w",
                                 tags={"pipeline": "p"})])
    got = live.wait_any(timeout=60)   # a finishes; wide cannot fit 1 device
    assert [t.desc.name for t in got] == ["a"]
    ex.inject_grow(["e0", "e1"])
    rep_thr = live.drain(timeout=60).close()
    assert rm.total == 3

    assert all(t.state == TaskState.DONE for t in rep_sim.tasks)
    assert all(t.state == TaskState.DONE for t in rep_thr.tasks)
    assert _elastic_key_trace(rep_sim) == _elastic_key_trace(rep_thr)
    # the acceptance property: the pending wide task dispatched in the SAME
    # scheduler step that absorbed the grow
    grow_t = next(e.t for e in rep_sim.trace if e.kind == "grow")
    disp_t = next(e.t for e in rep_sim.trace
                  if e.kind == "dispatch" and e.task == "wide")
    assert disp_t == pytest.approx(grow_t)
    assert next(e.value for e in rep_thr.trace if e.kind == "grow") == 2.0


def test_retire_trace_equivalence_sim_thread():
    """Graceful retire: free devices leave the pool without a
    device_failure, running tasks keep theirs until done — identical
    skeleton via ``retire_at`` (sim) and ``inject_retire`` (threads)."""
    specs = [("a", "p", 3.0), ("b", "p", 1.0)]
    sim = SchedulerSession(
        VirtualClockExecutor(SimOptions(noise=0.0,
                                        overhead_model=lambda r: 0.0,
                                        retire_at=[(2.0, 1)])),
        ResourceManager([0, 1]))
    rep_sim = sim.run(_sim_descs(specs))
    assert sim.rm.total == 1

    ex = ThreadExecutor(build_comm=False, tick=0.01)
    rm = ResourceManager(["d0", "d1"])
    live = SchedulerSession(ex, rm, tick=0.01)
    live.submit(_live_descs(specs, sleep_scale=0.1))
    got = live.wait_any(timeout=60)           # b vacates d1
    assert [t.desc.name for t in got] == ["b"]
    ex.inject_retire(["d1"])
    rep_thr = live.drain(timeout=60).close()
    assert rm.total == 1 and "d0" in rm and "d1" not in rm

    assert all(t.state == TaskState.DONE for t in rep_sim.tasks)
    assert all(t.state == TaskState.DONE for t in rep_thr.tasks)
    assert _elastic_key_trace(rep_sim) == _elastic_key_trace(rep_thr)
    for rep in (rep_sim, rep_thr):
        assert next(e.value for e in rep.trace if e.kind == "retire") == 1.0
        assert not rep.events("device_failure") and not rep.events("fail")


def test_same_core_reports_device_failure_trace():
    rep = simulate(
        [TaskDescription(name=f"t{i}", ranks=2, fn=None,
                         duration_model=lambda r: 10.0,
                         tags={"pipeline": "p"}) for i in range(4)],
        8, SimOptions(noise=0.0, device_failures=[(1.0, 2)]))
    assert len(rep.events("device_failure")) == 1
    assert all(t.state == TaskState.DONE for t in rep.tasks)


def test_trace_gantt_renders_lanes_and_utilization():
    """The Gantt renderer reconstructs per-device lanes from the TraceEvent
    stream alone: 4 devices, 2-rank tasks back to back -> 4 lanes, full
    legend, and a sensible overall utilization figure."""
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.report import trace_gantt

    descs = [TaskDescription(name=f"t{i}", ranks=2, fn=None,
                             duration_model=lambda r: 5.0,
                             tags={"pipeline": "p"}) for i in range(4)]
    rep = simulate(descs, 4, SimOptions(noise=0.0))
    art = trace_gantt(rep, width=40)
    lines = art.splitlines()
    assert sum(1 for ln in lines if ln.startswith("dev")) == 4
    assert all(f"t{i}" in art for i in range(4))
    util = float(art.rsplit(":", 1)[1].rstrip("%"))
    assert 50.0 < util <= 100.0    # 4 equal tasks on 4 devices, 2 waves
