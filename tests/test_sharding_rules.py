"""Sharding rules: every param leaf gets a valid spec; divisibility
fallbacks; cache specs; HLO collective parser on known programs."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ParallelConfig, get_config, list_archs
from repro.distributed import sharding as sh
from repro.models import registry


class FakeMesh:
    """Shape-only mesh stand-in (rules never touch devices)."""
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np
        self.devices = _np.empty(shape)


MESH = FakeMesh((16, 16), ("data", "model"))


@pytest.mark.parametrize("arch", list_archs())
def test_every_param_has_valid_spec(arch):
    cfg = get_config(arch)
    shapes = registry.eval_params_shape(cfg)
    specs = sh.param_specs(shapes, MESH, ParallelConfig(), cfg)

    def check(path, leaf, spec):
        assert isinstance(spec, P)
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        sizes = {"data": 16, "model": 16}
        for dim, entry in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([sizes[a] for a in axes]))
            assert dim % total == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def test_internvl_heads_fall_back_to_replicated():
    """14 heads % 16 != 0 -> heads axis must NOT be sharded."""
    cfg = get_config("internvl2-1b")
    shapes = registry.eval_params_shape(cfg)
    specs = sh.param_specs(shapes, MESH, ParallelConfig(), cfg)
    wq_spec = specs["blocks"]["attn"]["wq"]
    assert "model" not in jax.tree.leaves(tuple(wq_spec) or (None,)), wq_spec


def test_qwen3_heads_sharded():
    cfg = get_config("qwen3-8b")
    shapes = registry.eval_params_shape(cfg)
    specs = sh.param_specs(shapes, MESH, ParallelConfig(), cfg)
    assert specs["blocks"]["attn"]["wq"][-2] == "model"
    # kv heads = 8 < 16 -> replicated
    assert specs["blocks"]["attn"]["wk"][-2] is None


def test_expert_weights_ep_sharded():
    cfg = get_config("llama4-maverick-400b-a17b")
    shapes = registry.eval_params_shape(cfg)
    specs = sh.param_specs(shapes, MESH, ParallelConfig(), cfg)
    assert specs["blocks"]["moe"]["wg"][-3] == "model"


def test_cache_specs_context_sharding():
    """long_500k zamba2: B=1 unshardable -> seq context-sharded over data."""
    cfg = get_config("zamba2-7b")
    cache = registry.eval_cache_shape(cfg, 1, 524288)
    specs = sh.cache_specs(cfg, cache, MESH, ParallelConfig())
    kspec = specs["k"]
    assert kspec[-3] is not None     # seq sharded
    assert kspec[-2] == "model"      # kv heads 32 % 16 == 0
    assert kspec[-4] is None         # batch of 1 unsharded


def test_cache_specs_decode32k():
    cfg = get_config("qwen3-8b")
    cache = registry.eval_cache_shape(cfg, 128, 32768)
    specs = sh.cache_specs(cfg, cache, MESH, ParallelConfig())
    kspec = specs["k"]
    assert kspec[-4] in ("data", ("data",))   # batch over dp
    assert kspec[-3] == "model"      # kv=8 not divisible -> seq over model
    assert kspec[-2] is None


# ---------------------------------------------------------------------------
# HLO collective parser (roofline input) on programs with KNOWN collectives
# ---------------------------------------------------------------------------
@pytest.mark.integration
def test_parse_collectives_known_psum():
    from tests._subproc import run_with_devices
    out = run_with_devices(r"""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.dryrun import parse_collectives
mesh = jax.make_mesh((8,), ("d",), axis_types=(jax.sharding.AxisType.Auto,))
def f(x):
    return jax.lax.psum(x, "d")
g = jax.jit(jax.shard_map(f, mesh=mesh, in_specs=P("d"), out_specs=P()))
xs = jax.ShapeDtypeStruct((8, 1024), jnp.float32)
co = g.lower(xs).compile()
colls = parse_collectives(co.as_text(), pod_size=4)
ar = [c for c in colls if c["op"] == "all-reduce"]
assert len(ar) >= 1, colls
# result is (1024,) f32 per device -> 4096 bytes; ring traffic 2*(7/8)*4096
assert any(abs(c["traffic_bytes"] - 2*(7/8)*4096) < 1 for c in ar), ar
# group of 8 spans both "pods" of 4 under pod_size=4
assert any(c["dcn"] for c in ar)
print("PARSE_OK")
""", n_devices=8)
    assert "PARSE_OK" in out


def test_parse_groups_iota_transpose():
    from repro.launch.dryrun import _parse_groups
    # [4,2]<=[2,4]T(1,0): ids arange(8).reshape(2,4).T.reshape(4,2)
    gs, crosses = _parse_groups("[4,2]<=[2,4]T(1,0)", pod_size=4)
    assert gs == 2
    # groups: [0,4],[1,5],[2,6],[3,7] -> all cross pods of size 4
    assert crosses
    gs2, crosses2 = _parse_groups("{{0,1},{2,3}}", pod_size=4)
    assert gs2 == 2 and not crosses2
