"""Local dataframe operators vs numpy oracles — hypothesis property tests."""
import jax.numpy as jnp
import numpy as np

from tests._hypothesis_compat import given, settings, st

from repro.dataframe import ops_local as L
from repro.dataframe import reference as R
from repro.dataframe.table import Table, from_numpy

ints = st.integers(min_value=0, max_value=50)


def _table(keys, vals, capacity=None):
    return from_numpy({"k": np.asarray(keys, np.int32),
                       "v": np.asarray(vals, np.float32)},
                      capacity=capacity)


@settings(max_examples=30, deadline=None)
@given(st.lists(ints, min_size=1, max_size=40), st.integers(0, 20))
def test_sort_matches_numpy(keys, extra_cap):
    vals = np.arange(len(keys), dtype=np.float32)
    t = _table(keys, vals, capacity=len(keys) + extra_cap)
    out = L.sort_by(t, "k")
    got = out.to_numpy()
    ref = R.ref_sort({"k": np.asarray(keys, np.int32), "v": vals}, "k")
    np.testing.assert_array_equal(got["k"], ref["k"])
    # stable: values of equal keys keep order
    np.testing.assert_array_equal(got["v"], ref["v"])


@settings(max_examples=30, deadline=None)
@given(st.lists(ints, min_size=1, max_size=30),
       st.lists(ints, min_size=1, max_size=30))
def test_join_matches_numpy(lk, rk):
    left = {"k": np.asarray(lk, np.int32),
            "v": np.arange(len(lk), dtype=np.float32)}
    right = {"k": np.asarray(rk, np.int32),
             "w": np.arange(len(rk), dtype=np.float32) + 100}
    ref = R.ref_join_inner(left, right, "k")
    lt = from_numpy(left, capacity=len(lk) + 5)
    rt = from_numpy(right, capacity=len(rk) + 3)
    out_cap = max(len(ref["k"]), 1) + 8
    out, overflow = L.join_inner(lt, rt, "k", out_cap)
    assert not bool(overflow)
    got = out.to_numpy()
    assert len(got["k"]) == len(ref["k"])
    a = R.sorted_rows(got)
    b = R.sorted_rows(ref)
    np.testing.assert_allclose(a, b)


@settings(max_examples=30, deadline=None)
@given(st.lists(ints, min_size=1, max_size=40))
def test_groupby_sum_matches_numpy(keys):
    vals = np.random.default_rng(0).normal(size=len(keys)).astype(np.float32)
    data = {"k": np.asarray(keys, np.int32), "v": vals}
    t = from_numpy(data, capacity=len(keys) + 4)
    out = L.groupby_sum(t, "k", ["v"])
    got = out.to_numpy()
    ref = R.ref_groupby_sum(data, "k", ["v"])
    assert len(got["k"]) == len(ref["k"])
    o = np.argsort(got["k"])
    np.testing.assert_array_equal(got["k"][o], ref["k"])
    np.testing.assert_allclose(got["v"][o], ref["v"], atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(st.lists(st.booleans(), min_size=1, max_size=40))
def test_filter_compacts_stably(keep):
    n = len(keep)
    data = {"k": np.arange(n, dtype=np.int32),
            "v": np.arange(n, dtype=np.float32)}
    t = from_numpy(data, capacity=n + 3)
    keep_padded = np.concatenate([np.asarray(keep), np.zeros(3, bool)])
    out = L.filter_rows(t, jnp.asarray(keep_padded))
    got = out.to_numpy()
    want = data["k"][np.asarray(keep)]
    np.testing.assert_array_equal(got["k"], want)


def test_join_overflow_flag():
    left = {"k": np.zeros(10, np.int32), "v": np.arange(10, dtype=np.float32)}
    right = {"k": np.zeros(10, np.int32), "w": np.arange(10, dtype=np.float32)}
    lt = from_numpy(left)
    rt = from_numpy(right)
    out, overflow = L.join_inner(lt, rt, "k", out_capacity=16)  # needs 100
    assert bool(overflow)


def test_concat():
    a = from_numpy({"k": np.asarray([1, 2], np.int32)}, capacity=4)
    b = from_numpy({"k": np.asarray([3, 4, 5], np.int32)}, capacity=5)
    out = L.concat(a, b, capacity=8)
    np.testing.assert_array_equal(out.to_numpy()["k"], [1, 2, 3, 4, 5])


def test_to_numpy_on_distributed_table_delegates_to_collect():
    """A distributed Table carries a per-rank nrows VECTOR and rank-major
    padded columns; to_numpy must strip each rank's padding (collect_table
    semantics) instead of crashing on int(vector)."""
    # 2 ranks, capacity 3 each: rank0 holds [1, 2], rank1 holds [5]
    t = Table(columns={"k": jnp.asarray([1, 2, 0, 5, 0, 0], jnp.int32)},
              nrows=jnp.asarray([2, 1], jnp.int32))
    np.testing.assert_array_equal(t.to_numpy()["k"], [1, 2, 5])
    # the scalar (local) path is unchanged
    local = from_numpy({"k": np.asarray([7, 8], np.int32)}, capacity=4)
    np.testing.assert_array_equal(local.to_numpy()["k"], [7, 8])
