"""SSM invariants: chunked associative scan == sequential scan; mamba decode
steps == train-path outputs token by token."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import ssm


@pytest.mark.parametrize("chunk", [1, 4, 8, 32])
def test_chunked_scan_equals_sequential(chunk):
    key = jax.random.key(0)
    B, S, D, N = 2, 32, 6, 5
    a = jax.nn.sigmoid(jax.random.normal(key, (B, S, D, N)))  # stable decay
    b = jax.random.normal(jax.random.key(1), (B, S, D, N))
    h0 = jax.random.normal(jax.random.key(2), (B, D, N))
    hs, hfin = ssm._assoc_scan_chunked(a, b, h0, chunk)
    ref = ssm.reference_scan(a, b, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref), atol=1e-5)
    np.testing.assert_allclose(np.asarray(hfin), np.asarray(ref[:, -1]), atol=1e-5)


@pytest.mark.parametrize("version", [1, 2])
def test_mamba_decode_matches_train_path(version):
    arch = "falcon-mamba-7b" if version == 1 else "zamba2-7b"
    cfg = reduced(get_config(arch))
    init = ssm.mamba1_init if version == 1 else ssm.mamba2_init
    apply_ = ssm.mamba1_apply if version == 1 else ssm.mamba2_apply
    decode = ssm.mamba1_decode if version == 1 else ssm.mamba2_decode
    state_init = ssm.mamba1_state_init if version == 1 else ssm.mamba2_state_init

    p = init(jax.random.key(0), cfg, jnp.float32)
    B, S = 2, 10
    x = jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.3
    y_train = apply_(p, x, cfg)
    state = state_init(B, cfg, jnp.float32)
    ys = []
    for t in range(S):
        y_t, state = decode(p, x[:, t:t + 1], state, cfg)
        ys.append(y_t)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_train),
                               atol=2e-4, rtol=1e-3)


def test_causal_conv_matches_stepwise():
    B, S, C, K = 2, 9, 4, 4
    x = jax.random.normal(jax.random.key(0), (B, S, C))
    w = jax.random.normal(jax.random.key(1), (K, C))
    b = jax.random.normal(jax.random.key(2), (C,))
    full = ssm._causal_conv(x, w, b)
    state = jnp.zeros((B, K - 1, C))
    outs = []
    for t in range(S):
        y, state = ssm._conv_step(state, x[:, t], w, b)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=1e-5)
