"""Gradient compression with error feedback — distributed-optimization trick
for the DP all-reduce at 1000+ node scale.

int8 block quantization: per-block absmax scales, values quantized to int8.
The all-reduce then moves int16 accumulators (safe for group sums up to
256 ranks) — 2 bytes/elem instead of 4 (f32 grads) — and the residual
(quantization error) is fed back into the next step's gradient (error
feedback, Seide et al. style), which keeps SGD/Adam convergence.

Used inside shard_map over the DP axis; see ``compressed_psum_mean`` and
tests/test_compression.py for the convergence check.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    n = x.size
    pad = (-n) % BLOCK
    flat = x.reshape(-1)
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat, n, pad


def quantize_int8(x):
    """x any-shape float -> (q int8 (nblk, BLOCK), scales (nblk,), meta)."""
    flat, n, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, n)


def dequantize_int8(q, scale, meta):
    shape, n = meta
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape)


def compressed_psum_mean(x, axis: str, *, error: jnp.ndarray | None = None):
    """Mean-all-reduce of x over a named axis with int8 quantization and
    error feedback.  Returns (mean, new_error).

    The wire format is int16 (quantized values summed exactly across <= 256
    ranks); scales are f32 but tiny (1/BLOCK of the payload).  Net traffic:
    ~2 bytes/element vs 4 for f32 — 2x compression on the DP all-reduce.
    """
    n = jax.lax.axis_size(axis)
    xe = x + (error if error is not None else 0.0)
    q, scale, meta = quantize_int8(xe)
    local_deq = dequantize_int8(q, scale, meta)
    new_error = xe - local_deq
    # shared scale: use the max scale across ranks so integer sums commute
    gscale = jax.lax.pmax(scale, axis)
    requant = jnp.clip(
        jnp.round(local_deq_blocks(local_deq, meta) / gscale[:, None]),
        -127, 127).astype(jnp.int16)
    summed = jax.lax.psum(requant, axis)
    mean = (summed.astype(jnp.float32) * gscale[:, None] / n)
    return _unblock(mean, meta), new_error


def local_deq_blocks(x, meta):
    flat, _, _ = _pad_to_block(x)
    return flat.reshape(-1, BLOCK)


def _unblock(blocks, meta):
    shape, n = meta
    return blocks.reshape(-1)[:n].reshape(shape)


def tree_compressed_psum_mean(tree, axis: str, errors=None):
    """Apply compressed_psum_mean over a pytree; threads per-leaf error."""
    leaves, treedef = jax.tree.flatten(tree)
    errs = (treedef.flatten_up_to(errors) if errors is not None
            else [None] * len(leaves))
    outs, new_errs = [], []
    for leaf, err in zip(leaves, errs):
        m, e = compressed_psum_mean(leaf, axis, error=err)
        outs.append(m)
        new_errs.append(e)
    return jax.tree.unflatten(treedef, outs), jax.tree.unflatten(treedef, new_errs)
