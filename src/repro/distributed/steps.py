"""Step builders: jitted train / prefill / decode steps with explicit
in/out shardings, plus abstract-input builders for the AOT dry-run.

Every step is a plain function of pytrees, so ``jax.jit(...).lower(*abstract)``
works with ShapeDtypeStruct stand-ins (no allocation) — the multi-pod dry-run
path — and with concrete arrays for real training/serving.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.distributed.context import axes_ctx
from repro.models import registry
from repro.models.attention import AttnMode
from repro.train import optimizer as opt_mod


def _attn_mode(cfg: ModelConfig, parallel: ParallelConfig, seq_len: int) -> AttnMode:
    unroll = getattr(cfg, "unroll_scans", False)
    if seq_len <= 1024 and not unroll:
        return AttnMode(kind="full")
    blk = parallel.attn_block
    return AttnMode(kind="blockwise", q_block=blk, kv_block=blk,
                    causal_skip=cfg.causal_skip, unroll=unroll)


def _smax(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Cache length: VLM caches hold the patch prefix + text tokens."""
    return shape.seq_len + (cfg.n_patches if cfg.family == "vlm" else 0)


class StepBundle(NamedTuple):
    fn: Any                 # the jitted function
    abstract_args: tuple    # ShapeDtypeStructs for .lower()
    info: dict


def _sds(tree, mesh, specs):
    """ShapeDtypeStruct pytree with NamedShardings attached."""
    return jax.tree.map(
        lambda t, s: jax.ShapeDtypeStruct(t.shape, t.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree, specs,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jax.Array)))


def _batch_sds(shapes, mesh, specs):
    return {
        k: jax.ShapeDtypeStruct(shape, dt, sharding=NamedSharding(mesh, specs[k]))
        for k, (shape, dt) in shapes.items()
    }


# ---------------------------------------------------------------------------
# train step
# ---------------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, mesh, parallel: ParallelConfig,
                    shape: ShapeConfig, ocfg: opt_mod.OptimizerConfig | None = None):
    ocfg = ocfg or opt_mod.OptimizerConfig()
    api = registry.get_model(cfg)
    mode = _attn_mode(cfg, parallel, shape.seq_len)

    def loss_of(params, batch):
        return api.loss_fn(params, cfg, batch, mode)

    def train_step(params, opt_state, batch):
      with axes_ctx(mesh, parallel.moe_impl, parallel.dp_axes):
        if parallel.microbatches > 1:
            mb = parallel.microbatches
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            micro = jax.tree.map(split, batch)

            def acc_body(acc, mbatch):
                l, g = jax.value_and_grad(loss_of)(params, mbatch)
                return jax.tree.map(jnp.add, acc,
                                    jax.tree.map(lambda x: x / mb, (l, g))), None
            zero = (jnp.zeros((), jnp.float32),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(acc_body, zero, micro)
        else:
            loss, grads = jax.value_and_grad(loss_of)(params, batch)
        params, opt_state, metrics = opt_mod.adamw_update(grads, opt_state, params, ocfg)
        return params, opt_state, {"loss": loss, **metrics}

    # shardings
    params_shape = registry.eval_params_shape(cfg)
    pspecs = sh.param_specs(params_shape, mesh, parallel, cfg)
    opt_shape = jax.eval_shape(opt_mod.adamw_init, params_shape)
    ospecs = sh.opt_specs(opt_shape, pspecs)
    bshapes = registry.train_batch_shapes(cfg, shape.global_batch, shape.seq_len)
    bspecs = sh.batch_specs(bshapes, mesh, parallel)
    metric_specs = {"loss": P(), "lr": P(), "grad_norm": P()}

    jit_step = jax.jit(
        train_step,
        in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, ospecs),
                      sh.named(mesh, bspecs)),
        out_shardings=(sh.named(mesh, pspecs), sh.named(mesh, ospecs),
                       sh.named(mesh, metric_specs)),
        donate_argnums=(0, 1),
    )
    abstract = (
        _sds(params_shape, mesh, pspecs),
        _sds(opt_shape, mesh, ospecs),
        _batch_sds(bshapes, mesh, bspecs),
    )
    return StepBundle(jit_step, abstract,
                      {"pspecs": pspecs, "ospecs": ospecs, "bspecs": bspecs,
                       "mode": mode})


# ---------------------------------------------------------------------------
# prefill step
# ---------------------------------------------------------------------------
def make_prefill_step(cfg: ModelConfig, mesh, parallel: ParallelConfig,
                      shape: ShapeConfig):
    api = registry.get_model(cfg)
    mode = _attn_mode(cfg, parallel, shape.seq_len)
    smax = _smax(cfg, shape)

    def prefill_step(params, batch):
        with axes_ctx(mesh, parallel.moe_impl, parallel.dp_axes):
            cache, logits = api.prefill(params, cfg, batch, smax, mode)
            return cache, logits

    params_shape = registry.eval_params_shape(cfg)
    pspecs = sh.param_specs(params_shape, mesh, parallel, cfg)
    bshapes = registry.prefill_batch_shapes(cfg, shape.global_batch, shape.seq_len)
    bspecs = sh.batch_specs(bshapes, mesh, parallel)
    cache_shape = registry.eval_cache_shape(cfg, shape.global_batch, smax)
    cspecs = sh.cache_specs(cfg, cache_shape, mesh, parallel)
    logit_spec = P(sh.dp_axes(mesh, parallel)
                   if shape.global_batch % sh._dp_size(mesh, sh.dp_axes(mesh, parallel)) == 0
                   else None,
                   sh._axis_if(mesh, sh.TP_AXIS, cfg.vocab_size, parallel.tensor_parallel))

    jit_step = jax.jit(
        prefill_step,
        in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, bspecs)),
        out_shardings=(sh.named(mesh, cspecs), NamedSharding(mesh, logit_spec)),
    )
    abstract = (_sds(params_shape, mesh, pspecs), _batch_sds(bshapes, mesh, bspecs))
    return StepBundle(jit_step, abstract, {"pspecs": pspecs, "cspecs": cspecs})


# ---------------------------------------------------------------------------
# decode step
# ---------------------------------------------------------------------------
def make_decode_step(cfg: ModelConfig, mesh, parallel: ParallelConfig,
                     shape: ShapeConfig):
    api = registry.get_model(cfg)
    smax = _smax(cfg, shape)

    def decode_step(params, batch, cache):
        with axes_ctx(mesh, parallel.moe_impl, parallel.dp_axes):
            logits, cache = api.decode_step(params, cfg, batch, cache)
            return logits, cache

    params_shape = registry.eval_params_shape(cfg)
    pspecs = sh.param_specs(params_shape, mesh, parallel, cfg)
    bshapes = registry.decode_batch_shapes(cfg, shape.global_batch)
    bspecs = sh.batch_specs(bshapes, mesh, parallel)
    cache_shape = registry.eval_cache_shape(cfg, shape.global_batch, smax)
    cspecs = sh.cache_specs(cfg, cache_shape, mesh, parallel)
    dp = sh.dp_axes(mesh, parallel)
    logit_spec = P(dp if shape.global_batch % sh._dp_size(mesh, dp) == 0 else None,
                   sh._axis_if(mesh, sh.TP_AXIS, cfg.vocab_size, parallel.tensor_parallel))

    jit_step = jax.jit(
        decode_step,
        in_shardings=(sh.named(mesh, pspecs), sh.named(mesh, bspecs),
                      sh.named(mesh, cspecs)),
        out_shardings=(NamedSharding(mesh, logit_spec), sh.named(mesh, cspecs)),
        donate_argnums=(2,),
    )
    abstract = (
        _sds(params_shape, mesh, pspecs),
        _batch_sds(bshapes, mesh, bspecs),
        _sds(cache_shape, mesh, cspecs),
    )
    return StepBundle(jit_step, abstract, {"pspecs": pspecs, "cspecs": cspecs})


def make_step(cfg, mesh, parallel, shape):
    if shape.kind == "train":
        return make_train_step(cfg, mesh, parallel, shape)
    if shape.kind == "prefill":
        return make_prefill_step(cfg, mesh, parallel, shape)
    return make_decode_step(cfg, mesh, parallel, shape)
