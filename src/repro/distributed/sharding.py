"""Sharding rules: param/batch/cache pytrees -> PartitionSpec pytrees.

Strategy (per ParallelConfig):
  * TP   — heads / ff / experts / vocab over the 'model' axis, with a
           divisibility fallback to replication (e.g. internvl2's 14 heads).
  * FSDP — the 'embed'-like dim of every large weight over 'data'
           (ZeRO-3 style; gathered per-layer under scan).
  * DP   — batch dims over ('pod','data') (or what exists in the mesh).
  * KV cache — batch over DP; kv-heads over 'model' when divisible, else the
           sequence dim over 'model' (context-sharded cache).

All rules are *name+shape based* walks of the actual pytrees, so new modules
inherit sensible shardings without extra registration.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

TP_AXIS = "model"
FSDP_AXIS = "data"


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def dp_axes(mesh: Mesh, parallel: ParallelConfig):
    return tuple(a for a in parallel.dp_axes if a in mesh.axis_names)


def _div(n: int, k: int) -> bool:
    return k > 1 and n % k == 0


def _axis_if(mesh, axis, dim_size, enabled=True):
    return axis if (enabled and axis in mesh.axis_names
                    and _div(dim_size, mesh_axis_size(mesh, axis))) else None


def _fsdp_entry(mesh, parallel, dim_size):
    """Longest prefix of parallel.fsdp_axes whose product divides the dim."""
    if not parallel.fsdp:
        return None
    keep, prod = [], 1
    for a in parallel.fsdp_axes:
        n = mesh_axis_size(mesh, a)
        if a in mesh.axis_names and n > 1 and dim_size % (prod * n) == 0:
            keep.append(a)
            prod *= n
    if not keep:
        return None
    return tuple(keep) if len(keep) > 1 else keep[0]


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
_TP_LAST = {"wg", "wi"}         # (..., d, f): shard f (output dim)
_TP_FIRST = {"wo"}              # (..., f, d): shard f (input dim)
_REPLICATE = {"ln", "ln1", "ln2", "ln3", "final_norm", "enc_norm", "norm_w",
              "q_norm", "k_norm", "conv_b", "dt_bias", "A_log", "D",
              "shared_gate", "count"}


def _param_spec(path_keys, leaf, mesh, parallel: ParallelConfig, cfg: ModelConfig):
    name = path_keys[-1]
    tp_on = parallel.tensor_parallel
    shape = leaf.shape
    nd = leaf.ndim

    def spec(*trailing):
        """Pad with leading Nones for stacked layer dims."""
        return P(*([None] * (nd - len(trailing)) + list(trailing)))

    if name in _REPLICATE or nd == 0:
        return P()

    if name == "embedding":                      # (V, d)
        return spec(_axis_if(mesh, TP_AXIS, shape[-2], tp_on),
                    _fsdp_entry(mesh, parallel, shape[-1]))
    if name == "lm_head":                        # (d, V)
        return spec(_fsdp_entry(mesh, parallel, shape[-2]),
                    _axis_if(mesh, TP_AXIS, shape[-1], tp_on))
    if name in ("wq", "wk", "wv"):               # (..., d, H|K, hd)
        return spec(_fsdp_entry(mesh, parallel, shape[-3]),
                    _axis_if(mesh, TP_AXIS, shape[-2], tp_on),
                    None)
    if name == "wo" and nd >= 3 and shape[-2] == cfg.head_dim:
        # attention output proj (..., H, hd, d)
        return spec(_axis_if(mesh, TP_AXIS, shape[-3], tp_on),
                    None,
                    _fsdp_entry(mesh, parallel, shape[-1]))
    if name == "router":                         # (..., d, E)
        return spec(_fsdp_entry(mesh, parallel, shape[-2]), None)
    if name in ("wg", "wi", "wo") and nd >= 3 and cfg.n_experts and \
            shape[-3] == cfg.n_experts:          # (..., E, d, f) / (..., E, f, d)
        e_ax = _axis_if(mesh, TP_AXIS, shape[-3], tp_on)
        return spec(e_ax, _fsdp_entry(mesh, parallel, shape[-2]), None)
    if name in _TP_LAST and nd >= 2:             # (..., d, f)
        return spec(_fsdp_entry(mesh, parallel, shape[-2]),
                    _axis_if(mesh, TP_AXIS, shape[-1], tp_on))
    if name in _TP_FIRST and nd >= 2:            # (..., f, d)
        return spec(_axis_if(mesh, TP_AXIS, shape[-2], tp_on),
                    _fsdp_entry(mesh, parallel, shape[-1]))
    # SSM weights: FSDP-only in the baseline (no TP on mamba blocks —
    # documented; the perf pass revisits head-sharding for zamba2).
    if name in ("in_proj", "x_proj", "out_proj"):   # (..., big, small-or-big)
        return spec(_fsdp_entry(mesh, parallel, shape[-2]), None)
    if name == "dt_proj":                        # (..., dtr, di)
        return spec(None, _fsdp_entry(mesh, parallel, shape[-1]))
    if name == "conv_w":
        return P()
    if nd >= 2:
        # generic large 2D+: fsdp the second-to-last dim
        return spec(_fsdp_entry(mesh, parallel, shape[-2]), None)
    return P()


def param_specs(params, mesh, parallel: ParallelConfig, cfg: ModelConfig):
    def f(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        return _param_spec(keys, leaf, mesh, parallel, cfg)
    return jax.tree_util.tree_map_with_path(f, params)


def opt_specs(opt_shape, pspecs):
    """Optimizer moments shard exactly like params; count is replicated."""
    return {
        "mu": pspecs,
        "nu": pspecs,
        "count": P(),
    }


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(batch_shapes, mesh, parallel: ParallelConfig):
    dp = dp_axes(mesh, parallel)
    out = {}
    for name, (shape, _) in batch_shapes.items():
        bdim = dp if _div(shape[0], _dp_size(mesh, dp)) else None
        out[name] = P(*([bdim] + [None] * (len(shape) - 1)))
    return out


def _dp_size(mesh, dp):
    n = 1
    for a in dp:
        n *= mesh_axis_size(mesh, a)
    return n


def cache_specs(cfg: ModelConfig, cache_shape, mesh, parallel: ParallelConfig):
    """Walk the cache pytree (ShapeDtypeStructs or arrays)."""
    dp = dp_axes(mesh, parallel)
    dpn = _dp_size(mesh, dp)
    tpn = mesh_axis_size(mesh, TP_AXIS)
    tp_on = parallel.tensor_parallel

    def kv_spec(leaf):
        # (..., B, S, K, hd)
        nd = leaf.ndim
        b, s, k = leaf.shape[-4], leaf.shape[-3], leaf.shape[-2]
        b_ax = dp if _div(b, dpn) else None
        if tp_on and _div(k, tpn):
            k_ax, s_ax = TP_AXIS, None
        elif tp_on and _div(s, tpn):
            k_ax, s_ax = None, TP_AXIS
        else:
            k_ax = s_ax = None
        if b_ax is None and _div(s, dpn * (tpn if s_ax else 1)):
            # batch unshardable (e.g. long_500k B=1): context-shard over data too
            s_ax = tuple(dp) + ((TP_AXIS,) if s_ax else ())
        return P(*([None] * (nd - 4) + [b_ax, s_ax, k_ax, None]))

    def ssm_spec(leaf, kind):
        nd = leaf.ndim
        if kind == "conv":      # (..., B, k-1, C)
            b, c = leaf.shape[-3], leaf.shape[-1]
            return P(*([None] * (nd - 3) +
                       [dp if _div(b, dpn) else None, None,
                        _axis_if(mesh, TP_AXIS, c, tp_on)]))
        if cfg.ssm_version == 2:  # h: (..., B, nh, hd, st)
            b, nh = leaf.shape[-4], leaf.shape[-3]
            return P(*([None] * (nd - 4) +
                       [dp if _div(b, dpn) else None,
                        _axis_if(mesh, TP_AXIS, nh, tp_on), None, None]))
        b, di = leaf.shape[-3], leaf.shape[-2]   # h: (..., B, di, st)
        return P(*([None] * (nd - 3) +
                   [dp if _div(b, dpn) else None,
                    _axis_if(mesh, TP_AXIS, di, tp_on), None]))

    def f(path, leaf):
        keys = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = keys[-1]
        if name in ("k", "v", "xk", "xv"):
            return kv_spec(leaf)
        if name == "conv":
            return ssm_spec(leaf, "conv")
        if name == "h":
            return ssm_spec(leaf, "h")
        return P()

    return jax.tree_util.tree_map_with_path(f, cache_shape)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
