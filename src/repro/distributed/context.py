"""Trace-time mesh context so model code can apply
``with_sharding_constraint`` without plumbing the mesh everywhere.

steps.make_* wraps each step body in ``axes_ctx(mesh.axis_names)``; model
modules call ``constrain(x, 'data', None, 'model', ...)`` and the constraint
is applied only for axis names present in the ambient mesh (no-op in
single-device smoke tests).
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


@contextlib.contextmanager
def axes_ctx(mesh, moe_impl: str = "gspmd", dp=("pod", "data")):
    """Accepts a Mesh or a dict name->size."""
    is_mesh = hasattr(mesh, "axis_names")
    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if is_mesh else dict(mesh))
    prev = getattr(_state, "sizes", {})
    prev_mesh = getattr(_state, "mesh", None)
    prev_moe = getattr(_state, "moe_impl", "gspmd")
    prev_dp = getattr(_state, "dp", ("pod", "data"))
    _state.sizes = sizes
    _state.mesh = mesh if is_mesh else None
    _state.moe_impl = moe_impl
    _state.dp = tuple(dp)
    try:
        yield
    finally:
        _state.sizes = prev
        _state.mesh = prev_mesh
        _state.moe_impl = prev_moe
        _state.dp = prev_dp


def current_mesh():
    return getattr(_state, "mesh", None)


def current_moe_impl() -> str:
    return getattr(_state, "moe_impl", "gspmd")


def current_axes() -> dict:
    return getattr(_state, "sizes", {})


def _filter(entry, sizes, dim):
    """Keep only mesh axes that exist AND divide the dim size."""
    if entry is None:
        return None
    cand = entry if isinstance(entry, (tuple, list)) else (entry,)
    keep, prod = [], 1
    for a in cand:
        n = sizes.get(a, 0)
        if n and dim % (prod * n) == 0:
            keep.append(a)
            prod *= n
    if not keep:
        return None
    return tuple(keep) if len(keep) > 1 else keep[0]


def constrain(x, *spec):
    """Apply a PartitionSpec constraint, dropping axes that are absent from
    the ambient mesh or do not divide the dimension.  No-op without a mesh
    context (single-device smoke tests)."""
    sizes = current_axes()
    if not sizes:
        return x
    filtered = [_filter(e, sizes, d) for e, d in zip(spec, x.shape)]
    if all(e is None for e in filtered):
        return x
    return jax.lax.with_sharding_constraint(x, P(*filtered))


def current_dp() -> tuple:
    return getattr(_state, "dp", ("pod", "data"))


def shard_tokens(x):
    """Batch-shard an activation whose leading dim is (global) batch."""
    return constrain(x, current_dp(), *([None] * (x.ndim - 1)))


def shard_heads(x):
    """(B, S, H, hd): batch over DP, heads over TP."""
    dp = current_dp()
    return constrain(x, dp, None, "model" if "model" not in dp else None, None)


def shard_ff(x):
    """(..., f): batch over DP, ff/vocab dim over TP."""
    dp = current_dp()
    return constrain(x, dp, *([None] * (x.ndim - 2)),
                     "model" if "model" not in dp else None)
