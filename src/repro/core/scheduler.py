"""Unified event-driven scheduler core — the paper's contribution, written
ONCE against an abstract ``Executor`` so the *identical* scheduling code runs
(a) live on real JAX devices (``ThreadExecutor``), (b) on a virtual clock
at 84–2688 ranks (``VirtualClockExecutor``, the paper's ORNL-Summit scales),
and (c) across worker *processes* — one fresh interpreter per node with its
own host devices, heartbeat liveness, and cross-process per-task
communicators (``ProcessExecutor``, see ``repro.core.executors.proc``).

Two policies, mirroring the paper's §4.3 comparison:

* ``HETEROGENEOUS`` (Radical-Cylon): one shared pool; any released device
  immediately backfills any pending task from any pipeline.
* ``BATCH`` (LSF-style baseline): the pool is statically partitioned per
  pipeline; resources released by one pipeline are NOT available to others.
  Paper result: heterogeneous is 4–15 % faster at equal resources.

The core (``SchedulerSession``) owns policy, dispatch, retry with
device-exclusion, straggler detection with speculative re-execution, and
device-failure / elastic pool handling; the executor owns only the clock and
the mechanics of running one task.  Because the live executor is just another
backend, live mode gets retry-with-exclusion, spec-exec, stragglers, and
elastic shrink/grow for free — previously these existed only in the sim.

The session is persistent: tasks may be submitted while others run
(continuous DAG release, see ``core/pipeline.py``), and every lifecycle step
is appended to a per-task event trace (``TraceEvent``: submit / dispatch /
comm_build / done / fail / retry / speculate / cancel / device_failure /
steal / return / grow / retire / resume / cache_hit) consumed uniformly by
the benchmarks and ``SimReport``.

Long-running work survives churn cheaply: with ``ckpt_root`` (or
``REPRO_CKPT_DIR``) set, every launched attempt carries a checkpoint
namespace shared across the logical task's lineage, so retries and
spec-exec twins resume from the last durably completed step
(``resume`` trace event, ``resumed_from_step`` evidence); with
``result_cache`` (or ``REPRO_RESULT_CACHE``) naming a directory, a
resubmitted identical task completes straight from the stored result
(``cache_hit``) without dispatching.

The pool is elastic at runtime in BOTH directions on every backend: a
``grow`` event (``ProcessExecutor.add_worker``, ``inject_grow`` on live
executors, ``SimOptions.grow_at`` on the virtual clock) adds inventory and
backfills pending work in the same scheduler step; a ``retire`` event
(``ProcessExecutor.retire_worker``, ``inject_retire``, ``retire_at``)
withdraws inventory gracefully — draining tasks keep their devices until
they finish, the devices just never return to the free list.

Placement (``core/placement.py``) makes dispatch topology-aware: the core
asks the executor for its :class:`Topology` (node -> device handles) and
allocates through ``ResourceManager.allocate_placed`` under a placement
policy — ``spread`` (historical flat order) or ``pack`` (fewest nodes; on
the process executor a fitting task lands on ONE worker and its collectives
never touch the parent hub).  Under ``BATCH``, ``work_stealing=True`` makes
the static partitions elastic: a partition with a backlog leases idle
devices a sibling partition doesn't need (``steal`` trace event) and hands
them back on release (``return``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
import statistics
import threading
import time as _time
from pathlib import Path
from typing import Optional, Sequence

from repro.core.executors import serialize as _serialize

from repro.core.executors import (
    ExecEvent, Executor, ProcDevice, ProcessExecutor, SimOptions, StubComm,
    ThreadExecutor, VirtualClockExecutor, default_overhead_model,
)
from repro.core.pilot import InsufficientResources, ResourceManager
from repro.obs import trace as _obs_trace
from repro.core.placement import PACK, PLACEMENTS, SPREAD, Topology
from repro.core.task import Task, TaskDescription, TaskState

__all__ = [  # executor names are re-exported for historical import paths
    "BATCH", "HETEROGENEOUS", "PACK", "PLACEMENTS", "SPREAD", "ExecEvent",
    "Executor", "LiveScheduler", "ProcDevice", "ProcessExecutor",
    "SchedulerSession", "SimOptions", "SimReport", "StubComm",
    "ThreadExecutor", "Topology", "TraceEvent", "VirtualClockExecutor",
    "default_overhead_model", "interleave_by_pipeline", "simulate",
]

HETEROGENEOUS = "heterogeneous"
BATCH = "batch"

_SHARED = "_shared"


def interleave_by_pipeline(tasks):
    """Round-robin the pending queue across pipeline tags (stable within a
    pipeline, priority respected).  Prevents the convoy effect where one
    pipeline's long tasks monopolize the shared pool — without this, FIFO
    heterogeneous scheduling can lose to static batch partitions on
    imbalanced mixes (observed; see EXPERIMENTS.md §Perf notes)."""
    groups: dict = {}
    for t in tasks:
        groups.setdefault(t.desc.tags.get("pipeline", "default"), []).append(t)
    out = []
    while any(groups.values()):
        for g in list(groups):
            if groups[g]:
                out.append(groups[g].pop(0))
    out.sort(key=lambda t: -t.desc.priority)  # stable: RR preserved per prio
    return out


# ---------------------------------------------------------------------------
# event trace — one schema for sim and live, consumed by benchmarks/ and
# SimReport (schema documented in docs/ARCHITECTURE.md)
# ---------------------------------------------------------------------------

#: The closed vocabulary of ``TraceEvent.kind``.  Every ``_tr()`` call in
#: this module emits one of these, and docs/ARCHITECTURE.md documents each —
#: the docs-honesty check (tests/test_docs.py) holds both sides to it, so a
#: new kind cannot ship undeclared or undocumented.
TRACE_EVENT_KINDS = frozenset({
    "submit", "dispatch", "comm_build", "done", "fail", "retry", "speculate",
    "cancel", "device_failure", "steal", "return", "grow", "retire",
    "telemetry", "resume", "cache_hit",
})


@dataclasses.dataclass
class TraceEvent:
    t: float          # executor clock (virtual seconds or perf_counter)
    kind: str         # submit|dispatch|comm_build|done|fail|retry|speculate|
                      # cancel|device_failure|steal|return|grow|retire|
                      # telemetry|resume|cache_hit
    task: str = ""    # task name ("" for pool-level events)
    uid: int = -1
    pipeline: str = ""
    ranks: int = 0
    value: float = 0.0   # kind-specific payload (comm_build: seconds;
                         # device_failure: #devices lost; steal/return:
                         # #devices leased across partitions / handed back;
                         # grow/retire: #devices joining/leaving the pool;
                         # resume: checkpoint step the attempt restored)
    p2p: float = 0.0     # comm-stats evidence on terminal done/fail events:
                         # bytes the task's collectives moved worker-to-
                         # worker.  The process executor reports real bytes;
                         # sim/thread backends report 0 — same schema.
    spills: float = 0.0  # shuffle partitions the task spilled to disk
                         # (out-of-core shuffle evidence, same schema rule)
    data: dict = dataclasses.field(default_factory=dict)
                         # kind-specific structured payload: terminal events
                         # carry {hub_calls, p2p_fallbacks, hub_relay_bytes}
                         # (the comm-stats evidence trace_summary reports);
                         # telemetry events carry the worker id + its gauge
                         # snapshot.  Empty dict everywhere else — the
                         # schema never forks per backend.

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SimReport:
    makespan: float
    tasks: list
    overhead_total: float
    per_pipeline: dict
    n_speculative: int = 0
    n_retries: int = 0
    trace: list = dataclasses.field(default_factory=list)
    spans: list = dataclasses.field(default_factory=list)   # worker-side
    # flight-recorder spans aligned into the executor clock; empty on
    # backends without instrumented workers (sim/thread) — same schema
    telemetry: list = dataclasses.field(default_factory=list)   # heartbeat
    # gauge snapshots ({t, worker, queue_depth, rss_mb, ...}); empty on
    # sim/thread backends

    def pipeline_makespan(self, key: str) -> float:
        return self.per_pipeline.get(key, 0.0)

    def events(self, kind: Optional[str] = None) -> list:
        """Filter the event trace by kind (None -> whole trace)."""
        if kind is None:
            return list(self.trace)
        return [e for e in self.trace if e.kind == kind]


# ---------------------------------------------------------------------------
# the scheduler core
# ---------------------------------------------------------------------------
class SchedulerSession:
    """Persistent scheduling session over one executor + one device pool.

    Supports continuous task release: ``submit`` may be called at any time
    (e.g. the moment a DAG stage's deps complete) and freed devices backfill
    pending work immediately — no wave barrier.  ``wait_any`` blocks until at
    least one task reaches DONE/FAILED; ``drain`` runs everything to
    completion; ``close`` returns the ``SimReport`` with the event trace.
    """

    def __init__(self, executor: Executor, resource_manager: ResourceManager,
                 policy: str = HETEROGENEOUS,
                 pipelines: Optional[Sequence[str]] = None,
                 speculative_factor: Optional[float] = None,
                 tick: float = 0.05, placement: str = SPREAD,
                 work_stealing: bool = False,
                 trace_path: Optional[str] = None,
                 ckpt_root: Optional[str] = None,
                 result_cache: Optional[str] = None):
        if placement not in PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r}; expected "
                             f"one of {PLACEMENTS}")
        self.executor = executor
        self.rm = resource_manager
        self.policy = policy
        self.placement = placement
        self.work_stealing = work_stealing
        self.speculative_factor = speculative_factor
        self.tick = tick
        self.t0 = executor.now()
        self.tasks: list[Task] = []
        self.pending: list[Task] = []
        self.running: dict[int, Task] = {}
        self.trace: list[TraceEvent] = []
        self.spans: list[dict] = []      # worker flight-recorder spans,
        # parent-clock aligned (empty on sim/thread — same schema)
        self.telemetry: list[dict] = []  # heartbeat gauge snapshots
        # durable capture: every TraceEvent/span/telemetry record streams to
        # JSONL as it happens (crash-safe line-buffered writes) when
        # trace_path or the REPRO_TRACE env knob names a destination
        self._writer = None
        path = _obs_trace.resolve_trace_path(trace_path)
        if path:
            self._writer = _obs_trace.TraceWriter(path)
            self._writer.meta(
                n_devices=resource_manager.total, policy=policy,
                placement=placement, t0=self.t0,
                backend=type(executor).__name__,
                wall_clock=bool(executor.wall_clock))
        self.overhead_total = 0.0
        self.n_speculative = 0
        self.n_retries = 0
        self._done_durations: dict[str, list] = {}
        self._finished_uids: set = set()
        self._ignored: set = set()   # live attempts whose outcome no longer
        # matters (spec-exec losers): their event only releases devices
        self._declared = list(pipelines) if pipelines else []
        self._pools: Optional[dict[str, ResourceManager]] = None
        self._batch_devs: tuple = ()
        self._leases: dict[int, list] = {}   # uid -> [(lender_pool, devs)]:
        # work-stealing bookkeeping so released devices return to the
        # partition they were leased from, never the thief's own pool
        self._max_timeout = 0.0   # largest wait budget seen; sizes the reaper
        # crash-safe resume: every attempt of one logical task checkpoints
        # under <ckpt_root>/t<primary_uid>, so retries and spec-exec twins
        # restore the doomed attempt's last durable step (REPRO_CKPT_DIR)
        if ckpt_root is None:
            ckpt_root = os.environ.get("REPRO_CKPT_DIR", "")
        self.ckpt_root = ckpt_root or None
        # result memoization keyed on (fn, args, kwargs, ranks) digests:
        # a repeated identical DAG run completes finished stages straight
        # from disk with a cache_hit event (REPRO_RESULT_CACHE=<dir>, "0"
        # or empty disables; live executors only — sim results are fake)
        if result_cache is None:
            result_cache = os.environ.get("REPRO_RESULT_CACHE", "")
        self.result_cache = None if result_cache in ("", "0") else result_cache
        self._cache_done: list[Task] = []   # cache-completed tasks awaiting
        # delivery through wait_any, so drain()/run_pipelines see them

    # -- trace ------------------------------------------------------------
    def _tr(self, kind: str, task: Optional[Task] = None, t: Optional[float] = None,
            value: float = 0.0, p2p: float = 0.0, spills: float = 0.0,
            data: Optional[dict] = None):
        ev = TraceEvent(
            t=self.executor.now() if t is None else t, kind=kind,
            task=task.desc.name if task else "",
            uid=task.uid if task else -1,
            pipeline=task.desc.tags.get("pipeline", "default") if task else "",
            ranks=task.desc.ranks if task else 0, value=value, p2p=p2p,
            spills=spills, data=data or {})
        self.trace.append(ev)
        if self._writer is not None:
            self._writer.event(ev)

    def _record_spans(self, spans):
        if not spans:
            return
        self.spans.extend(spans)
        if self._writer is not None:
            for s in spans:
                self._writer.span(s)

    # -- pools ------------------------------------------------------------
    def _ensure_pools(self, descs: Sequence[TaskDescription]):
        if self._pools is not None:
            if self.policy == BATCH:
                unknown = {d.tags.get("pipeline", "default") for d in descs} \
                    - set(self._pools)
                if unknown:
                    raise InsufficientResources(
                        f"batch policy: pipelines {sorted(unknown)} were not "
                        f"declared when the pool was partitioned; pass "
                        f"pipelines=[...] at session start")
            return
        if self.policy == BATCH:
            pipes = sorted(set(self._declared)
                           | {d.tags.get("pipeline", "default") for d in descs})
            share = self.rm.total // len(pipes)
            if share == 0:
                raise InsufficientResources(
                    f"batch policy: {len(pipes)} pipelines over "
                    f"{self.rm.total} devices leaves 0 devices per partition")
            devs = self.rm.allocate(share * len(pipes))
            self._batch_devs = devs
            self._pools = {p: ResourceManager(devs[i * share:(i + 1) * share])
                           for i, p in enumerate(pipes)}
        else:
            self._pools = {_SHARED: self.rm}

    def _pool_of(self, task: Task) -> ResourceManager:
        if self.policy == BATCH:
            return self._pools[task.desc.tags.get("pipeline", "default")]
        return self._pools[_SHARED]

    # -- public API -------------------------------------------------------
    def submit(self, descs: Sequence[TaskDescription]) -> list[Task]:
        """Enqueue tasks; dispatches immediately onto any free devices."""
        descs = list(descs)
        for d in descs:
            if self.executor.wall_clock and d.fn is None:
                raise ValueError(
                    f"task {d.name!r}: fn is required for live execution "
                    f"(duration_model alone only drives the virtual clock)")
            if not self.executor.wall_clock and d.duration_model is None:
                raise ValueError(
                    f"task {d.name!r}: duration_model is required on the "
                    f"virtual clock")
        self._ensure_pools(descs)
        now = self.executor.now()
        tasks = [Task(desc=d) for d in descs]
        for t in tasks:
            t.state = TaskState.PENDING
            t.submit_time = now
            self._tr("submit", t, t=now)
        self.tasks.extend(tasks)
        for t in tasks:
            if not self._cache_load(t):
                self.pending.append(t)
        self._dispatch()
        return tasks

    @property
    def outstanding(self) -> int:
        """Tasks still owed a terminal state.  Spec-exec losers do not
        count: their live threads may linger, but the workload result no
        longer depends on them."""
        return len(self.pending) + len(self._cache_done) + sum(
            1 for uid in self.running if uid not in self._ignored)

    def wait_any(self, timeout: Optional[float] = None) -> list[Task]:
        """Block until >=1 task finishes (DONE or FAILED); returns them.
        An empty list means stuck (nothing running and pending tasks cannot
        dispatch) or timeout."""
        finished: list[Task] = []
        if self._cache_done:
            # cache-completed tasks never touch the executor; deliver them
            # like any other completion so DAG drivers release dependents
            finished, self._cache_done = self._cache_done, []
        enforce = timeout is not None and self.executor.wall_clock
        if enforce:
            self._max_timeout = max(self._max_timeout, timeout)
        start = self.executor.now()
        while not finished:
            if enforce and self.executor.now() - start > timeout:
                break
            active = any(uid not in self._ignored for uid in self.running)
            if not active and not self.pending:
                break                          # fully drained (canceled
                                               # threads may still linger)
            if not self.running:
                self._dispatch()               # elastic grow may unblock us
                if self.running:
                    continue
                ev = self.executor.poll(self.tick)
                if ev is None:
                    break                      # virtual clock: truly stuck
                if ev.kind == "tick":
                    if enforce:
                        continue   # live + deadline: keep waiting — an
                                   # elastic grow may make pending feasible
                    break          # no deadline to bound the wait: stuck
                finished.extend(self._handle(ev))
                continue
            ev = self.executor.poll(self.tick)
            if ev is None:
                break   # virtual clock exhausted with tasks in flight: bug
            if ev.kind == "tick":
                self._maybe_speculate()
                self._dispatch()
                continue
            finished.extend(self._handle(ev))
        # opportunistically absorb events that are already ready
        while True:
            ev = self.executor.poll(0)
            if ev is None:
                break
            if ev.kind != "tick":
                finished.extend(self._handle(ev))
        return finished

    def drain(self, timeout: Optional[float] = None) -> "SchedulerSession":
        """Run until every submitted task reached a terminal state, the
        queue is stuck, or — on a wall-clock executor — ``timeout`` expires.
        Timeouts are a hang guard and are NOT applied to virtual clocks,
        whose runs always terminate on their own."""
        if not self.executor.wall_clock:
            timeout = None
        t_end = None if timeout is None else self.executor.now() + timeout
        while self.outstanding:
            remaining = None if t_end is None else t_end - self.executor.now()
            if remaining is not None and remaining <= 0:
                break
            got = self.wait_any(timeout=remaining)
            if not got and not self.running:
                break   # stuck: pending tasks can never dispatch
        return self

    def close(self) -> SimReport:
        """Return the report; batch partitions are handed back to the pool."""
        # spec-exec losers and (on a failure teardown) still-running sibling
        # tasks hold devices their live threads are still using; they are
        # reclaimed by the background reaper below as each thread actually
        # finishes — never eagerly, which would double-issue a busy device.
        if self._batch_devs:
            # hand partitions back to the parent pool, but (a) never a device
            # a still-running worker thread holds — it stays allocated rather
            # than being double-issued — and (b) never a device that failed
            # during the session: propagate the failure to the parent so dead
            # devices stay dead.
            busy = {d for t in self.running.values() for d in t.devices}
            dead = set()
            for pool in self._pools.values():
                dead |= pool.failed_devices
            self.rm.fail_devices([d for d in self._batch_devs if d in dead])
            self.rm.release([d for d in self._batch_devs
                             if d not in busy and d not in dead])
            self._batch_devs = ()
        if self.running:
            # live worker threads may outlive the session (e.g. a sibling
            # task mid-run when a stage failure tears the DAG down).  Their
            # devices cannot be released while busy, so reap in the
            # background: as each thread delivers its event, hand the
            # devices back to the caller's ResourceManager.
            leftovers = {uid: t for uid, t in self.running.items()}
            executor, rm = self.executor, self.rm
            # outlive any wait budget the session was driven with, so a
            # legitimately long sibling task finishing inside its timeout
            # always gets its devices returned
            deadline = _time.monotonic() + max(600.0, 2 * self._max_timeout)

            def _reap():
                remaining = set(leftovers)
                while remaining and _time.monotonic() < deadline:
                    ev = executor.poll(1.0)
                    if ev is None:
                        return
                    t = ev.task
                    if t is not None and t.uid in remaining:
                        remaining.discard(t.uid)
                        rm.release(t.devices)

            threading.Thread(target=_reap, daemon=True).start()
            self.running = {}
        t0 = self.t0
        done = [t for t in self.tasks if t.state == TaskState.DONE]
        makespan = max((t.end_time for t in done),
                       default=self.executor.now()) - t0
        per_pipeline: dict[str, float] = {}
        for t in done:
            key = t.desc.tags.get("pipeline", "default")
            per_pipeline[key] = max(per_pipeline.get(key, 0.0),
                                    t.end_time - t0)
        if self._writer is not None:
            self._writer.close()
            self._writer = None
        return SimReport(makespan=makespan, tasks=list(self.tasks),
                         overhead_total=self.overhead_total,
                         per_pipeline=per_pipeline,
                         n_speculative=self.n_speculative,
                         n_retries=self.n_retries, trace=list(self.trace),
                         spans=list(self.spans),
                         telemetry=list(self.telemetry))

    def run(self, descs: Sequence[TaskDescription],
            timeout: Optional[float] = None) -> SimReport:
        """Convenience: submit everything, drain, close."""
        self.submit(descs)
        self.drain(timeout=timeout)
        return self.close()

    def record_telemetry(self, snapshot: dict, worker: str = "app"):
        """Public telemetry hook: surface an application-level gauge/counter
        snapshot (e.g. the serve tier's queue depth and slot occupancy) as a
        ``telemetry`` TraceEvent — the SAME stream worker heartbeats feed,
        so the flight recorder, ``load_trace`` and the Perfetto exporter
        pick application gauges up with zero extra plumbing."""
        rec = dict(snapshot)
        rec.setdefault("t", self.executor.now())
        rec.setdefault("worker", worker)
        self.telemetry.append(rec)
        self._tr("telemetry", t=rec["t"], data=rec)
        if self._writer is not None:
            self._writer.telemetry(rec)

    # -- checkpoint + result cache ----------------------------------------
    def _bind_ckpt(self, task: Task):
        """Stamp the attempt's checkpoint namespace before launch.  Every
        attempt of one logical task — primary retries ``a0, a1, ...`` and
        spec-exec twins ``s<uid>`` — shares ``<ckpt_root>/t<primary_uid>``,
        so a relaunch reads the doomed attempt's durable steps while writing
        only into its own attempt dir (see ``train.checkpoint``)."""
        if not self.ckpt_root:
            task.ckpt_dir = ""
            task.ckpt_attempt = ""
            return
        primary_uid = task.speculative_of \
            if task.speculative_of is not None else task.uid
        task.ckpt_dir = os.path.join(self.ckpt_root, f"t{primary_uid}")
        task.ckpt_attempt = (f"s{task.uid}" if task.speculative_of is not None
                             else f"a{task.retries}")

    def _cache_key(self, desc: TaskDescription) -> str:
        """Digest of (fn, args, kwargs, ranks) — "" when uncacheable (no fn,
        or the payload does not serialize deterministically)."""
        if desc.fn is None:
            return ""
        try:
            h = hashlib.sha256()
            h.update(_serialize.dumps((desc.fn, desc.args, desc.kwargs)))
            h.update(str(desc.ranks).encode())
            return h.hexdigest()
        except Exception:
            return ""

    def _cache_load(self, task: Task) -> bool:
        """Try to complete ``task`` straight from the result cache.  On a
        hit the task never dispatches: it goes DONE with the deserialized
        (bit-identical) stored result, emits ``cache_hit``, and is delivered
        through the next ``wait_any`` like any other completion."""
        if not (self.result_cache and self.executor.wall_clock):
            return False
        task.cache_key = self._cache_key(task.desc)
        if not task.cache_key:
            return False
        try:
            blob = (Path(self.result_cache)
                    / f"{task.cache_key}.pkl").read_bytes()
            result = _serialize.loads(blob)
        except Exception:
            return False   # miss, or a torn/unreadable entry: recompute
        now = self.executor.now()
        task.state = TaskState.DONE
        task.result = result
        task.cache_hit = True
        task.start_time = now
        task.end_time = now
        self._finished_uids.add(task.uid)
        self._tr("cache_hit", task, t=now)
        self._tr("done", task, t=now, data={"cache_hit": True})
        self._cache_done.append(task)
        return True

    def _cache_store(self, task: Task):
        """Persist a DONE task's result (tmp + os.replace, so concurrent
        sessions sharing a cache dir never observe a torn entry)."""
        if not (self.result_cache and task.cache_key) or task.cache_hit:
            return
        try:
            blob = _serialize.dumps(task.result)
        except Exception:
            return   # unserializable result: simply not cacheable
        try:
            root = Path(self.result_cache)
            root.mkdir(parents=True, exist_ok=True)
            tmp = root / f".{task.cache_key}.tmp.{os.getpid()}"
            tmp.write_bytes(blob)
            os.replace(tmp, root / f"{task.cache_key}.pkl")
        except OSError:
            return

    # -- internals --------------------------------------------------------
    def _allocate(self, pool: ResourceManager, n: int, exclude) -> tuple:
        """All scheduler allocations flow through the placement layer: the
        executor's topology report + the session's placement policy decide
        WHICH free devices a task gets, not just how many."""
        return pool.allocate_placed(n, topology=self.executor.topology,
                                    policy=self.placement, exclude=exclude)

    def _pending_need(self) -> dict:
        """Per-pool rank demand of the pending queue (keyed by pool id) —
        the floor below which a partition will not lend devices.  Computed
        once per dispatch sweep, decremented as tasks dispatch, so a deep
        backlog stays O(pending) per sweep instead of O(pending^2)."""
        need: dict[int, int] = {}
        for p in self.pending:
            pid = id(self._pool_of(p))
            need[pid] = need.get(pid, 0) + p.desc.ranks
        return need

    def _try_steal(self, task: Task, home: ResourceManager,
                   pending_need: dict) -> bool:
        """BATCH elasticity via work-stealing: when ``task`` overflows its
        own static partition, lease the shortfall from sibling partitions
        that have idle devices beyond their OWN pending demand (the
        ``pending_need`` floor; the thief's own demand presses only on its
        home pool, which is never a lender to itself).  Leased devices are
        tracked per task and handed back to their lender on release
        (``steal``/``return`` trace events) — the partitions stay
        statically owned, only idle capacity moves."""
        need = task.desc.ranks - home.n_free
        offers: list = []
        offered = 0
        for victim in self._pools.values():
            if victim is home or offered == need:
                continue
            spare = victim.n_free - pending_need.get(id(victim), 0)
            take = min(max(spare, 0), need - offered)
            if take > 0:
                offers.append((victim, take))
                offered += take
        if offered < need:
            return False
        leases = []
        stolen: list = []
        for victim, take in offers:
            got = self._allocate(victim, take, task.excluded_devices)
            leases.append((victim, got))
            stolen.extend(got)
        own = self._allocate(home, task.desc.ranks - len(stolen),
                             task.excluded_devices)
        task.devices = tuple(own) + tuple(stolen)
        self._leases[task.uid] = leases
        self._tr("steal", task, value=float(len(stolen)))
        return True

    def _release_task(self, task: Task):
        """Hand a task's devices back: leased devices return to the
        partition that lent them (``return`` trace event), the rest to the
        task's home pool.  The event's value counts devices ACTUALLY handed
        back — a leased device that died mid-lease left the lender's
        inventory via its device_failure event and must not be double-
        counted as returned."""
        leases = self._leases.pop(task.uid, None)
        if not leases:
            self._pool_of(task).release(task.devices)
            return
        leased: set = set()
        returned = 0
        for lender, devs in leases:
            returned += sum(1 for d in devs if d in lender)
            lender.release(devs)
            leased.update(devs)
        self._pool_of(task).release([d for d in task.devices
                                     if d not in leased])
        self._tr("return", task, value=float(returned))

    def _dispatch(self):
        progressed = True
        stealing = self.work_stealing and self.policy == BATCH
        while progressed:
            progressed = False
            pending_need = self._pending_need() if stealing else None
            for task in interleave_by_pipeline(list(self.pending)):
                pool = self._pool_of(task)
                if pool.n_free >= task.desc.ranks:
                    task.devices = self._allocate(pool, task.desc.ranks,
                                                  task.excluded_devices)
                elif not (stealing
                          and self._try_steal(task, pool, pending_need)):
                    continue
                if pending_need is not None:   # dispatched: its demand no
                    pending_need[id(pool)] -= task.desc.ranks   # longer queues
                self.pending.remove(task)
                task.state = TaskState.RUNNING
                task.placement = self.placement
                task.start_time = self.executor.now()
                self.running[task.uid] = task
                self._bind_ckpt(task)
                self._tr("dispatch", task)
                self.executor.launch(task)
                progressed = True

    def _maybe_speculate(self):
        """Spec-exec: if a running task exceeds factor x median of completed
        same-name tasks, launch a duplicate on free resources."""
        if not self.speculative_factor:
            return
        now = self.executor.now()
        for task in list(self.running.values()):
            if task.speculative_of is not None or \
                    task.uid in self._ignored or \
                    task.uid in self._finished_uids:
                # never duplicate a duplicate, a canceled loser whose live
                # thread lingers, or a task already decided
                continue
            hist = self._done_durations.get(task.desc.name)
            if not hist or len(hist) < 3:
                continue
            med = statistics.median(hist)
            if now - task.start_time > self.speculative_factor * med:
                pool = self._pool_of(task)
                if pool.n_free >= task.desc.ranks and \
                        not any(r.speculative_of == task.uid
                                for r in self.running.values()):
                    dup = Task(desc=task.desc)
                    dup.speculative_of = task.uid
                    dup.state = TaskState.RUNNING
                    dup.submit_time = now
                    dup.start_time = now
                    dup.placement = self.placement
                    dup.devices = self._allocate(pool, task.desc.ranks,
                                                 set(task.devices))
                    self.running[dup.uid] = dup
                    self._bind_ckpt(dup)
                    self._tr("speculate", dup)
                    self.executor.launch(dup, duration_hint=med)
                    self.n_speculative += 1

    def _cancel_twin(self, primary_uid: int):
        # a retry-pending primary whose duplicate already finished must be
        # purged from the queue, or it would be dispatched (and executed)
        # a second time after being marked DONE
        for p in list(self.pending):
            if p.uid == primary_uid or p.speculative_of == primary_uid:
                self.pending.remove(p)
        for r in list(self.running.values()):
            if r.uid == primary_uid or r.speculative_of == primary_uid:
                r.state = TaskState.CANCELED
                self._tr("cancel", r)
                if self.executor.cancel(r):
                    del self.running[r.uid]
                    self._release_task(r)
                else:
                    # the live thread finishes on its own; its event only
                    # releases the devices in _handle
                    self._ignored.add(r.uid)

    def _grow_pool(self) -> ResourceManager:
        """Where grown inventory lands: the shared pool (HETEROGENEOUS), or
        the parent pool under BATCH — the static partitions stay exactly as
        declared, so new devices are parent leftovers until a future session
        repartitions over them."""
        if self._pools and _SHARED in self._pools:
            return self._pools[_SHARED]
        return self.rm

    def _invent_devices(self, n: int) -> tuple:
        """Anonymous grow (virtual-clock injection): invent ``n`` fresh
        handles that cannot collide with live, busy, or previously failed
        inventory — an all-int pool (the sim's rank ids) keeps growing the
        integer range so ``SimOptions.devices_per_node`` topologies stay
        well-defined on the new devices."""
        known = set(self.rm.all_devices) | self.rm.failed_devices
        for pool in (self._pools or {}).values():
            known |= set(pool.all_devices) | pool.failed_devices
        if known and all(isinstance(d, int) for d in known):
            base = max(known) + 1
            return tuple(range(base, base + n))
        out, i = [], 0
        while len(out) < n:
            h = f"grown{i}"
            if h not in known:
                out.append(h)
            i += 1
        return tuple(out)

    def _handle(self, ev: ExecEvent) -> list[Task]:
        now = self.executor.now()
        if ev.kind == "telemetry":
            # a worker heartbeat's gauge snapshot: surfaced as a periodic
            # trace event so a stuck or swapping worker (climbing RSS, flat
            # queue) is visible in the recorded trace BEFORE it misses
            # liveness and becomes a device_failure
            rec = dict(ev.telemetry or {})
            rec.setdefault("t", now)
            rec["worker"] = ev.worker
            self.record_telemetry(rec, worker=ev.worker)
            return []
        if ev.kind == "grow":
            # elastic grow: the executor (ProcessExecutor.add_worker /
            # inject_grow) names the exact joining handles; the virtual
            # clock's grow_at injection leaves them anonymous and the core
            # invents fresh ones.  Pending work becomes feasible in the SAME
            # scheduler step: _dispatch runs before this event returns.
            devs = tuple(ev.devices) or self._invent_devices(ev.n_devices)
            pool = self._grow_pool()
            fresh = [d for d in devs if d not in pool]
            pool.add_devices(fresh)
            self._tr("grow", value=float(len(fresh)))
            self._dispatch()
            return []
        if ev.kind in ("device_failure", "retire"):
            if ev.devices:
                # targeted (process executor: a crashed worker's exact
                # inventory dies, or a retiring worker's inventory stops
                # being leased — busy or free).  Partition pools are checked
                # first; in BATCH the rounding leftovers live in the parent
                # pool.  Busy departed devices stay marked failed, so the
                # release() in their task's terminal event is a no-op — a
                # draining retire lets the task finish, but its devices
                # never return to the free list.
                pools = list(self._pools.values()) if self._pools else []
                if self.rm not in pools:
                    pools.append(self.rm)
                n, seen = 0, set()
                for pool in pools:
                    hit = [d for d in ev.devices
                           if d not in seen and d in pool]
                    if hit:
                        pool.fail_devices(hit)
                        seen.update(hit)
                        n += len(hit)
            else:
                # anonymous shrink (virtual-clock injection): lose up to
                # n_devices arbitrary FREE devices
                pool = max((self._pools or {_SHARED: self.rm}).values(),
                           key=lambda p: p.n_free)
                n = min(ev.n_devices, pool.n_free)
                if n:
                    pool.fail_devices(pool.allocate(n))
            self._tr(ev.kind, value=float(n))   # devices LOST/retired, which
            # may be fewer than requested when the pool is busy
            self._dispatch()
            return []

        task = ev.task
        if task.uid not in self.running:
            return []    # event for a task already aborted by the executor
        del self.running[task.uid]
        self._release_task(task)
        # comm-stats evidence travels with the completion event (last
        # attempt wins on retries); 0 on backends without a cross-process
        # data plane, real bytes/round-trips on the process executor
        task.p2p_bytes = ev.p2p_bytes
        task.hub_calls = ev.hub_calls
        task.spills = ev.spills
        task.p2p_fallbacks = ev.p2p_fallbacks
        task.hub_relay_bytes = ev.hub_relay_bytes
        task.raw_coll_bytes = ev.raw_coll_bytes
        task.shm_bytes = ev.shm_bytes
        task.ring_steps = ev.ring_steps
        task.resumed_from_step = ev.resumed_from_step
        # worker flight-recorder spans arrive piggybacked on the terminal
        # event, already aligned into this executor's clock
        self._record_spans(ev.spans)
        stats = {"hub_calls": ev.hub_calls,
                 "p2p_fallbacks": ev.p2p_fallbacks,
                 "hub_relay_bytes": ev.hub_relay_bytes,
                 "raw_coll_bytes": ev.raw_coll_bytes,
                 "shm_bytes": ev.shm_bytes,
                 "ring_steps": ev.ring_steps,
                 "resumed_from_step": ev.resumed_from_step}
        if task.uid in self._ignored:
            self._ignored.discard(task.uid)
            self._dispatch()   # live twin finished after cancel: reclaim only
            return []
        if ev.comm_build_s:
            task.comm_build_time = ev.comm_build_s
            self.overhead_total += ev.comm_build_s
            self._tr("comm_build", task, t=task.start_time + ev.comm_build_s,
                     value=ev.comm_build_s)
        if ev.resumed_from_step:
            # crash-safe resume evidence: this attempt restored the lineage's
            # durable step N instead of re-running from scratch
            self._tr("resume", task, value=float(ev.resumed_from_step))

        primary_uid = task.speculative_of if task.speculative_of is not None \
            else task.uid

        if ev.kind == "fail" and task.speculative_of is not None:
            # a speculative duplicate died: the primary is still running and
            # must not be cancelled or credited — just reclaim the devices
            task.state = TaskState.FAILED
            task.error = ev.error
            self._tr("fail", task, p2p=float(ev.p2p_bytes),
                     spills=float(ev.spills), data=stats)
            self._dispatch()
            return []

        if ev.kind == "fail" and task.speculative_of is None:
            task.retries += 1
            self.n_retries += 1
            task.excluded_devices |= set(task.devices)
            if task.retries <= task.desc.max_retries:
                task.state = TaskState.PENDING
                self._tr("retry", task)
                self.pending.append(task)
                self._dispatch()
                return []
            task.state = TaskState.FAILED
            task.error = ev.error
            task.end_time = now
            self._tr("fail", task, p2p=float(ev.p2p_bytes),
                     spills=float(ev.spills), data=stats)
            # terminal: a still-running speculative duplicate must not flip
            # this task back to DONE later
            self._finished_uids.add(task.uid)
            self._cancel_twin(task.uid)
            self._dispatch()
            return [task]

        if primary_uid in self._finished_uids:
            self._dispatch()
            return []
        self._finished_uids.add(primary_uid)
        self._cancel_twin(primary_uid)
        target = task if task.speculative_of is None else \
            next(t for t in self.tasks if t.uid == primary_uid)
        target.state = TaskState.DONE
        target.end_time = now
        target.result = ev.result
        target.p2p_bytes = ev.p2p_bytes
        target.hub_calls = ev.hub_calls
        target.spills = ev.spills
        target.p2p_fallbacks = ev.p2p_fallbacks
        target.hub_relay_bytes = ev.hub_relay_bytes
        target.raw_coll_bytes = ev.raw_coll_bytes
        target.shm_bytes = ev.shm_bytes
        target.ring_steps = ev.ring_steps
        target.resumed_from_step = ev.resumed_from_step
        self._done_durations.setdefault(target.desc.name, []).append(
            now - target.start_time)
        self._cache_store(target)
        self._tr("done", target, p2p=float(ev.p2p_bytes),
                 spills=float(ev.spills), data=stats)
        self._maybe_speculate()
        self._dispatch()
        return [target]


# ---------------------------------------------------------------------------
# the two historical entry points, now thin shims over the unified core
# ---------------------------------------------------------------------------
def simulate(descs: Sequence[TaskDescription], n_devices: int,
             opts: Optional[SimOptions] = None) -> SimReport:
    """Event-driven virtual-clock execution of ``descs`` on ``n_devices``.

    Deterministic for a given seed.  Each TaskDescription must provide
    ``duration_model(ranks) -> seconds`` and ``tags['pipeline']``.
    """
    opts = opts or SimOptions()
    rm = ResourceManager(list(range(n_devices)))
    sess = SchedulerSession(VirtualClockExecutor(opts), rm,
                            policy=opts.policy,
                            speculative_factor=opts.speculative_factor,
                            placement=opts.placement,
                            work_stealing=opts.work_stealing)
    return sess.run(descs)


class LiveScheduler:
    """Runs TaskDescriptions on real devices.  fn(comm, *args) is executed in
    a worker thread with a freshly built private Communicator; released
    devices backfill pending tasks (heterogeneous policy) or stay inside
    their pipeline partition (batch policy).

    Thin facade over ``SchedulerSession`` + a live executor — the same
    dispatch/retry/spec-exec code path as ``simulate``.  The backend is
    selectable: the default ``ThreadExecutor`` runs tasks in-process; pass a
    started :class:`ProcessExecutor` (whose ``resource_manager()`` supplied
    the device pool) to run the same workload across worker processes."""

    def __init__(self, resource_manager: ResourceManager,
                 policy: str = HETEROGENEOUS,
                 speculative_factor: Optional[float] = None,
                 executor: Optional[Executor] = None,
                 placement: str = SPREAD, work_stealing: bool = False):
        self.rm = resource_manager
        self.policy = policy
        self.placement = placement
        self.work_stealing = work_stealing
        self.speculative_factor = speculative_factor
        self.executor = executor
        self.tasks: list[Task] = []

    def run(self, descs: Sequence[TaskDescription],
            timeout: float = 600.0) -> SimReport:
        sess = SchedulerSession(self.executor or ThreadExecutor(), self.rm,
                                policy=self.policy,
                                speculative_factor=self.speculative_factor,
                                placement=self.placement,
                                work_stealing=self.work_stealing)
        rep = sess.run(descs, timeout=timeout)
        self.tasks = rep.tasks
        return rep
