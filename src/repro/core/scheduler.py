"""Schedulers — the paper's contribution, isolated from the executor so the
SAME scheduling logic runs (a) live on real JAX devices (threads) and (b) on a
virtual clock at 84–2688 ranks (the paper's ORNL-Summit scales).

Two policies, mirroring the paper's §4.3 comparison:

* ``HETEROGENEOUS`` (Radical-Cylon): one shared pool; any released device
  immediately backfills any pending task from any pipeline.
* ``BATCH`` (LSF-style baseline): the pool is statically partitioned per
  pipeline; resources released by one pipeline are NOT available to others.
  Paper result: heterogeneous is 4–15 % faster at equal resources.

Also implements, for scale-out readiness: retry-on-failure, device-failure
(pool shrink) handling, straggler detection with speculative re-execution,
and priority+FIFO dispatch with backfill.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import statistics
from typing import Callable, Optional, Sequence

from repro.core.task import Task, TaskDescription, TaskState

HETEROGENEOUS = "heterogeneous"
BATCH = "batch"


def interleave_by_pipeline(tasks):
    """Round-robin the pending queue across pipeline tags (stable within a
    pipeline, priority respected).  Prevents the convoy effect where one
    pipeline's long tasks monopolize the shared pool — without this, FIFO
    heterogeneous scheduling can lose to static batch partitions on
    imbalanced mixes (observed; see EXPERIMENTS.md §Perf notes)."""
    groups: dict = {}
    for t in tasks:
        groups.setdefault(t.desc.tags.get("pipeline", "default"), []).append(t)
    out = []
    while any(groups.values()):
        for g in list(groups):
            if groups[g]:
                out.append(groups[g].pop(0))
    out.sort(key=lambda t: -t.desc.priority)  # stable: RR preserved per prio
    return out


# ---------------------------------------------------------------------------
# calibrated models (defaults measured on this container; see
# benchmarks/bench_overhead.py which re-measures and can override)
# ---------------------------------------------------------------------------
def default_overhead_model(ranks: int) -> float:
    """Communicator-construction + task-description overhead (seconds).
    The paper's Table 2 reports 2.3-3.5 s, roughly flat in ranks; our JAX
    sub-mesh build is milliseconds, so the sim uses the paper-calibrated
    constants to reproduce Table 2, while bench_overhead.py reports our own
    measured numbers."""
    return 2.8 + 0.0012 * ranks


@dataclasses.dataclass
class SimReport:
    makespan: float
    tasks: list
    overhead_total: float
    per_pipeline: dict
    n_speculative: int = 0
    n_retries: int = 0

    def pipeline_makespan(self, key: str) -> float:
        return self.per_pipeline.get(key, 0.0)


@dataclasses.dataclass
class SimOptions:
    policy: str = HETEROGENEOUS
    overhead_model: Callable[[int], float] = default_overhead_model
    noise: float = 0.02                  # lognormal sigma on durations
    seed: int = 0
    straggler_prob: float = 0.0          # chance a task runs slow
    straggler_slowdown: float = 3.0
    speculative_factor: Optional[float] = None   # e.g. 1.5 -> spec-exec on
    failure_prob: float = 0.0            # chance a task attempt fails
    device_failures: Sequence[tuple] = ()  # [(time_s, n_devices), ...]


def simulate(descs: Sequence[TaskDescription], n_devices: int,
             opts: SimOptions = SimOptions()) -> SimReport:
    """Event-driven virtual-clock execution of ``descs`` on ``n_devices``.

    Deterministic for a given seed.  Each TaskDescription must provide
    ``duration_model(ranks) -> seconds`` and ``tags['pipeline']``.
    """
    import random
    rng = random.Random(opts.seed)
    tasks = [Task(desc=d) for d in descs]
    for t in tasks:
        t.state = TaskState.PENDING

    # --- resource pools -----------------------------------------------------
    if opts.policy == BATCH:
        pipelines = sorted({t.desc.tags.get("pipeline", "default") for t in tasks})
        share = n_devices // len(pipelines)
        free = {p: share for p in pipelines}
    else:
        free = {"_shared": n_devices}

    def pool_of(task: Task) -> str:
        if opts.policy == BATCH:
            return task.desc.tags.get("pipeline", "default")
        return "_shared"

    # --- event loop ---------------------------------------------------------
    seq = itertools.count()
    events: list = []   # (time, seq, kind, payload)
    now = 0.0
    pending: list[Task] = sorted(tasks, key=lambda t: -t.desc.priority)
    running: dict[int, Task] = {}
    done_durations: dict[str, list] = {}
    overhead_total = 0.0
    n_spec = 0
    n_retries = 0
    finished_uids: set = set()

    for ft, nf in opts.device_failures:
        heapq.heappush(events, (ft, next(seq), "device_failure", nf))

    def duration_of(task: Task) -> float:
        base = task.desc.duration_model(task.desc.ranks)
        base *= math.exp(rng.gauss(0.0, opts.noise))
        if opts.straggler_prob and rng.random() < opts.straggler_prob:
            base *= opts.straggler_slowdown
        return base

    def try_dispatch():
        nonlocal overhead_total, now
        progressed = True
        while progressed:
            progressed = False
            for task in interleave_by_pipeline(list(pending)):
                pool = pool_of(task)
                if free.get(pool, 0) >= task.desc.ranks:
                    free[pool] -= task.desc.ranks
                    pending.remove(task)
                    oh = opts.overhead_model(task.desc.ranks)
                    overhead_total += oh
                    task.comm_build_time = oh
                    task.start_time = now
                    task.state = TaskState.RUNNING
                    running[task.uid] = task
                    dur = duration_of(task)
                    fails = opts.failure_prob and rng.random() < opts.failure_prob
                    kind = "task_fail" if fails else "task_done"
                    heapq.heappush(events, (now + oh + dur, next(seq), kind, task))
                    progressed = True

    def maybe_speculate():
        """Spec-exec: if a running task exceeds factor x median of completed
        same-name tasks, launch a duplicate on free resources."""
        nonlocal n_spec
        if not opts.speculative_factor:
            return
        for task in list(running.values()):
            if task.speculative_of is not None:
                continue
            hist = done_durations.get(task.desc.name)
            if not hist or len(hist) < 3:
                continue
            med = statistics.median(hist)
            if now - task.start_time > opts.speculative_factor * med:
                pool = pool_of(task)
                if free.get(pool, 0) >= task.desc.ranks and \
                        not any(r.speculative_of == task.uid for r in running.values()):
                    dup = Task(desc=task.desc)
                    dup.speculative_of = task.uid
                    dup.state = TaskState.RUNNING
                    dup.start_time = now
                    free[pool] -= dup.desc.ranks
                    running[dup.uid] = dup
                    # duplicate runs at the *median* rate (fresh device)
                    heapq.heappush(events, (now + med, next(seq), "task_done", dup))
                    n_spec += 1

    try_dispatch()
    while events:
        now, _, kind, payload = heapq.heappop(events)
        if kind == "device_failure":
            n = payload
            pool = max(free, key=lambda p: free[p])
            free[pool] = max(0, free[pool] - n)
            try_dispatch()
            continue
        task = payload
        if task.uid not in running:      # canceled (spec-exec race)
            continue
        del running[task.uid]
        free[pool_of(task)] += task.desc.ranks
        primary_uid = task.speculative_of if task.speculative_of is not None else task.uid

        if kind == "task_fail" and task.speculative_of is None:
            task.retries += 1
            n_retries += 1
            if task.retries <= task.desc.max_retries:
                task.state = TaskState.PENDING
                pending.append(task)
            else:
                task.state = TaskState.FAILED
                task.end_time = now
            try_dispatch()
            continue

        if primary_uid in finished_uids:
            try_dispatch()
            continue
        finished_uids.add(primary_uid)
        # cancel the twin (primary or duplicate) if still running
        for r in list(running.values()):
            if r.uid == primary_uid or r.speculative_of == primary_uid:
                free[pool_of(r)] += r.desc.ranks
                r.state = TaskState.CANCELED
                del running[r.uid]
        target = task if task.speculative_of is None else \
            next(t for t in tasks if t.uid == primary_uid)
        target.state = TaskState.DONE
        target.end_time = now
        done_durations.setdefault(target.desc.name, []).append(
            now - target.start_time)
        maybe_speculate()
        try_dispatch()

    per_pipeline: dict[str, float] = {}
    for t in tasks:
        if t.state == TaskState.DONE:
            key = t.desc.tags.get("pipeline", "default")
            per_pipeline[key] = max(per_pipeline.get(key, 0.0), t.end_time)
    makespan = max((t.end_time for t in tasks if t.state == TaskState.DONE),
                   default=0.0)
    return SimReport(makespan=makespan, tasks=tasks,
                     overhead_total=overhead_total, per_pipeline=per_pipeline,
                     n_speculative=n_spec, n_retries=n_retries)


# ---------------------------------------------------------------------------
# live scheduler: real JAX devices, thread-dispatched SPMD payloads
# ---------------------------------------------------------------------------
class LiveScheduler:
    """Runs TaskDescriptions on real devices.  fn(comm, *args) is executed in
    a worker thread with a freshly built private Communicator; released
    devices backfill pending tasks (heterogeneous policy) or stay inside
    their pipeline partition (batch policy)."""

    def __init__(self, resource_manager, policy: str = HETEROGENEOUS):
        from repro.core.pilot import ResourceManager
        self.rm = resource_manager
        self.policy = policy
        self.tasks: list[Task] = []
        self._partitions: Optional[dict] = None

    def run(self, descs: Sequence[TaskDescription], timeout: float = 600.0):
        import queue
        import threading
        import time as _time

        from repro.core.communicator import build_communicator
        from repro.core.pilot import ResourceManager

        tasks = [Task(desc=d) for d in descs]
        for t in tasks:
            t.state = TaskState.PENDING
            t.submit_time = _time.perf_counter()
        self.tasks = tasks

        if self.policy == BATCH:
            pipes = sorted({t.desc.tags.get("pipeline", "default") for t in tasks})
            share = self.rm.total // len(pipes)
            devs = self.rm.allocate(share * len(pipes))
            pools = {p: ResourceManager(devs[i * share:(i + 1) * share])
                     for i, p in enumerate(pipes)}
        else:
            pools = {"_shared": self.rm}

        def pool_of(t):
            return pools[t.desc.tags.get("pipeline", "default")
                         if self.policy == BATCH else "_shared"]

        doneq: "queue.Queue" = queue.Queue()
        pending = list(tasks)
        n_running = 0

        def worker(task: Task, devices):
            try:
                comm = build_communicator(devices, task.desc.mesh_axes,
                                          task.desc.mesh_shape,
                                          uid=f"task{task.uid}")
                task.comm_build_time = comm.build_seconds
                res = task.desc.fn(comm, *task.desc.args, **task.desc.kwargs)
                doneq.put((task, devices, res, None))
            except Exception as e:  # noqa: BLE001 — report any payload error
                doneq.put((task, devices, None, f"{type(e).__name__}: {e}"))

        def try_dispatch():
            nonlocal n_running
            for task in interleave_by_pipeline(list(pending)):
                pool = pool_of(task)
                if pool.n_free >= task.desc.ranks:
                    devices = pool.allocate(task.desc.ranks)
                    pending.remove(task)
                    task.state = TaskState.RUNNING
                    task.start_time = _time.perf_counter()
                    task.devices = devices
                    n_running += 1
                    threading.Thread(target=worker, args=(task, devices),
                                     daemon=True).start()

        t_start = _time.perf_counter()
        try_dispatch()
        while (pending or n_running) and _time.perf_counter() - t_start < timeout:
            try:
                task, devices, res, err = doneq.get(timeout=1.0)
            except Exception:
                continue
            n_running -= 1
            pool_of(task).release(devices)
            task.end_time = _time.perf_counter()
            if err is None:
                task.state = TaskState.DONE
                task.result = res
            else:
                task.retries += 1
                if task.retries <= task.desc.max_retries:
                    task.state = TaskState.PENDING
                    pending.append(task)
                else:
                    task.state = TaskState.FAILED
                    task.error = err
            try_dispatch()

        makespan = max((t.end_time for t in tasks if t.state == TaskState.DONE),
                       default=_time.perf_counter()) - t_start
        return SimReport(
            makespan=makespan, tasks=tasks,
            overhead_total=sum(t.comm_build_time for t in tasks),
            per_pipeline={}, n_retries=sum(t.retries for t in tasks))
