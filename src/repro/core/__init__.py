"""The paper's primary contribution in JAX: a heterogeneous pilot runtime
(RADICAL-Pilot/RAPTOR analogue) that executes differently-sized SPMD tasks —
Cylon-style dataframe ops and LM train/serve steps — on dynamically carved
sub-meshes with private communicators, plus the batch-execution baseline it
is compared against in the paper."""
from repro.core.communicator import Communicator, build_communicator
from repro.core.pilot import (
    InsufficientResources, Pilot, PilotDescription, PilotManager,
    ResourceManager,
)
from repro.core.pipeline import Pipeline, run_pipelines
from repro.core.raptor import RaptorMaster, session
from repro.core.scheduler import (
    BATCH, HETEROGENEOUS, LiveScheduler, SimOptions, SimReport,
    default_overhead_model, simulate,
)
from repro.core.task import Task, TaskDescription, TaskState

__all__ = [
    "BATCH", "HETEROGENEOUS", "Communicator", "InsufficientResources",
    "LiveScheduler", "Pilot", "PilotDescription", "PilotManager", "Pipeline",
    "RaptorMaster", "ResourceManager", "SimOptions", "SimReport", "Task",
    "TaskDescription", "TaskState", "build_communicator",
    "default_overhead_model", "run_pipelines", "session", "simulate",
]
