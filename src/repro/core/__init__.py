"""The paper's primary contribution in JAX: a heterogeneous pilot runtime
(RADICAL-Pilot/RAPTOR analogue) that executes differently-sized SPMD tasks —
Cylon-style dataframe ops and LM train/serve steps — on dynamically carved
sub-meshes with private communicators, plus the batch-execution baseline it
is compared against in the paper."""
from repro.core.communicator import (
    Communicator, build_communicator, degenerate_axes,
)
from repro.core.pilot import (
    InsufficientResources, Pilot, PilotDescription, PilotManager,
    ResourceManager,
)
from repro.core.pipeline import Pipeline, Stage, run_pipelines
from repro.core.raptor import RaptorMaster, session
from repro.core.scheduler import (
    BATCH, HETEROGENEOUS, PACK, PLACEMENTS, SPREAD, ExecEvent, Executor,
    LiveScheduler, ProcDevice, ProcessExecutor, SchedulerSession, SimOptions,
    SimReport, StubComm, ThreadExecutor, Topology, TraceEvent,
    VirtualClockExecutor, default_overhead_model, interleave_by_pipeline,
    simulate,
)
from repro.core.task import Task, TaskDescription, TaskState

__all__ = [
    "BATCH", "HETEROGENEOUS", "PACK", "PLACEMENTS", "SPREAD", "Communicator",
    "ExecEvent", "Executor", "InsufficientResources", "LiveScheduler",
    "Pilot", "PilotDescription", "PilotManager", "Pipeline", "ProcDevice",
    "ProcessExecutor", "RaptorMaster", "ResourceManager", "SchedulerSession",
    "SimOptions", "SimReport", "Stage", "StubComm", "Task", "TaskDescription",
    "TaskState", "ThreadExecutor", "Topology", "TraceEvent",
    "VirtualClockExecutor", "build_communicator", "default_overhead_model",
    "degenerate_axes", "interleave_by_pipeline", "run_pipelines", "session",
    "simulate",
]
