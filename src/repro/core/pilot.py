"""Pilot abstraction: acquire a device pool once, then let the scheduler carve
it up per task (the paper's core resource-management idea).

ResourceManager models the HPC RM (Slurm/LSF): it owns the device inventory,
honours allocate/release, and supports *failure injection* (devices lost at
runtime) plus *elastic* grow/shrink — the fault-tolerance hooks exercised by
tests/test_runtime.py.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Optional, Sequence


class InsufficientResources(Exception):
    pass


@dataclasses.dataclass
class PilotDescription:
    n_devices: int
    name: str = "pilot"


class ResourceManager:
    """Device inventory with allocate/release and failure injection.

    Devices are any hashable handles; in real mode they are jax.Device
    objects, in simulation they are integer rank ids.
    """

    def __init__(self, devices: Sequence):
        self._lock = threading.Lock()
        self._all = list(devices)
        self._free = list(devices)
        self._failed: set = set()

    @property
    def total(self) -> int:
        return len(self._all)

    @property
    def n_free(self) -> int:
        with self._lock:
            return len(self._free)

    @property
    def failed_devices(self) -> set:
        with self._lock:
            return set(self._failed)

    @property
    def all_devices(self) -> tuple:
        """Snapshot of the full inventory (free AND busy), in pool order —
        what an elastic grow must not collide with when inventing handles."""
        with self._lock:
            return tuple(self._all)

    def __contains__(self, device) -> bool:
        """True while the device is part of this inventory (free OR busy);
        failed devices have left the inventory."""
        with self._lock:
            return device in self._all

    def allocate(self, n: int, exclude: Sequence = ()) -> tuple:
        """Historical flat allocation: first ``n`` free devices in pool
        order, excluded devices last.  Shim over :meth:`allocate_placed`
        with no topology — i.e. the ``spread`` placement."""
        return self.allocate_placed(n, exclude=exclude)

    def allocate_placed(self, n: int, topology=None,
                        policy: Optional[str] = None,
                        exclude: Sequence = ()) -> tuple:
        """Allocate ``n`` devices honouring a placement policy.

        ``topology`` is a :class:`repro.core.placement.Topology` over (a
        superset of) this pool's devices, or a callable producing one from
        the current free list — the scheduler passes the executor's
        ``topology`` method so grouping happens atomically under the pool
        lock.  ``policy`` is ``"spread"`` (historical flat order; default)
        or ``"pack"`` (fewest distinct nodes; see ``placement.plan``).
        Devices in ``exclude`` are chosen only when nothing else fits (the
        retry-with-device-exclusion contract)."""
        from repro.core.placement import SPREAD, _exclude_last, plan
        with self._lock:
            if len(self._free) < n:
                raise InsufficientResources(f"want {n}, free {len(self._free)}")
            if policy is None or policy == SPREAD:
                # the historical flat path, preserved EXACTLY — including the
                # excluded-last reordering persisting into the remaining free
                # list — so pre-placement schedules reproduce bit-for-bit;
                # the topology is never materialized here (spread ignores it)
                ordered = _exclude_last(self._free, set(exclude))
                got, self._free = ordered[:n], ordered[n:]
                return tuple(got)
            if callable(topology):
                topology = topology(tuple(self._free))
            got = plan(n, self._free, topology, policy, exclude)
            taken = set(got)
            self._free = [d for d in self._free if d not in taken]
            return tuple(got)

    def release(self, devices: Sequence):
        with self._lock:
            # snapshot sets once: membership scans on the raw lists would be
            # O(pool) per device, quadratic at paper-scale (2688) pools
            owned, free = set(self._all), set(self._free)
            for d in devices:
                # the membership check on _free guards against double
                # release: the same handle appended twice would satisfy two
                # concurrent allocations with one physical device
                if d not in self._failed and d in owned and d not in free:
                    self._free.append(d)
                    free.add(d)

    def fail_devices(self, devices: Sequence):
        """Failure injection: devices die; running tasks on them must retry."""
        with self._lock:
            self._failed.update(devices)
            self._all = [d for d in self._all if d not in self._failed]
            self._free = [d for d in self._free if d not in self._failed]

    def add_devices(self, devices: Sequence):
        """Elastic grow.  Handles already in the inventory are skipped, so
        replaying a grow event against a pool that absorbed it (executor-side
        AND session-side registration paths) stays idempotent — a duplicate
        handle in ``_free`` would satisfy two allocations with one device.
        An admitted handle is also cleared from the failed set: re-adding a
        previously failed/retired device is a rehabilitation (the node came
        back), and a handle left in ``_failed`` would be silently dropped by
        ``release`` after its first lease — a permanent pool leak."""
        with self._lock:
            known = set(self._all)
            for d in devices:
                if d not in known:
                    self._all.append(d)
                    self._free.append(d)
                    self._failed.discard(d)
                    known.add(d)


class Pilot:
    """An acquired resource pool (placeholder for compute, as in RP)."""

    def __init__(self, desc: PilotDescription, rm: ResourceManager):
        self.desc = desc
        self.rm = rm
        self.devices = rm.allocate(desc.n_devices)
        self._own_rm = ResourceManager(self.devices)

    @property
    def resource_manager(self) -> ResourceManager:
        return self._own_rm

    def cancel(self):
        self.rm.release(self.devices)


class PilotManager:
    """Owns pilots over a global inventory (rp.PilotManager analogue)."""

    def __init__(self, devices: Optional[Sequence] = None):
        if devices is None:
            import jax
            devices = jax.devices()
        self.global_rm = ResourceManager(devices)
        self.pilots: list[Pilot] = []

    def submit_pilot(self, desc: PilotDescription) -> Pilot:
        p = Pilot(desc, self.global_rm)
        self.pilots.append(p)
        return p
