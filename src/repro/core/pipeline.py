"""MPMD pipelines: DAGs of SPMD tasks (the paper's 'traced program comprising
multiple computations').

A Pipeline is a set of named stages with dependencies.  ``run_pipelines``
drives one persistent :class:`SchedulerSession` with *continuous DAG
release*: every stage is submitted the moment its OWN deps complete — not
when a whole frontier drains — so independent branches across concurrent
pipelines backfill freed devices immediately (paper §4.4: 'identifying
independent branches of execution and executing such independent tasks
parallelly', and the §4.3 heterogeneous-backfill win).  The previous
implementation executed DAGs in waves with a full barrier between frontiers,
which let freed devices idle until the slowest stage of a wave finished —
exactly the convoy effect the paper's runtime eliminates.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

from repro.core.scheduler import (
    HETEROGENEOUS, SPREAD, Executor, SchedulerSession, SimReport,
    ThreadExecutor,
)
from repro.core.task import TaskDescription, TaskState


@dataclasses.dataclass
class Stage:
    name: str
    ranks: int
    fn: Optional[Callable]  # fn(comm, *dep_results, **kwargs); None in sim
    deps: tuple = ()
    kwargs: dict = dataclasses.field(default_factory=dict)
    mesh_axes: tuple = ("df",)
    pipeline: str = "default"
    priority: int = 0
    duration_model: Optional[Callable[[int], float]] = None  # sim mode


class Pipeline:
    def __init__(self, name: str = "pipeline"):
        self.name = name
        self.stages: dict[str, Stage] = {}

    def add(self, name: str, ranks: int, fn: Optional[Callable] = None,
            deps: Sequence[str] = (), priority: int = 0,
            duration_model: Optional[Callable] = None,
            **kwargs) -> "Pipeline":
        assert name not in self.stages
        for d in deps:
            assert d in self.stages, f"unknown dep {d}"
        self.stages[name] = Stage(name=name, ranks=ranks, fn=fn,
                                  deps=tuple(deps), kwargs=kwargs,
                                  pipeline=self.name, priority=priority,
                                  duration_model=duration_model)
        return self

    def topo_order(self) -> list[str]:
        order, seen = [], set()

        def visit(n):
            if n in seen:
                return
            for d in self.stages[n].deps:
                visit(d)
            seen.add(n)
            order.append(n)

        for n in self.stages:
            visit(n)
        return order


def run_pipelines(pipelines: Sequence[Pipeline], resource_manager,
                  policy: str = HETEROGENEOUS, timeout: float = 600.0,
                  executor: Optional[Executor] = None,
                  placement: str = SPREAD, work_stealing: bool = False):
    """Execute several MPMD pipelines concurrently on one device pool.

    Continuous dependency release: each stage is submitted to the persistent
    scheduler session the moment its own deps complete, so a freed device is
    never held hostage by an unrelated still-running sibling stage.  Pass a
    :class:`VirtualClockExecutor` as ``executor`` to run the same DAG logic
    on the virtual clock (stages then need ``duration_model`` instead of
    ``fn``).  ``placement`` selects the topology policy (``spread``/``pack``,
    see ``core/placement.py``); ``work_stealing=True`` lets BATCH partitions
    lease each other's idle devices.  Returns ``(results, report)`` where
    ``report.trace`` holds the per-task event timeline."""
    results: dict[tuple, Any] = {}
    remaining = {(p.name, s): p.stages[s] for p in pipelines for s in p.stages}
    sess = SchedulerSession(executor or ThreadExecutor(), resource_manager,
                            policy=policy,
                            pipelines=[p.name for p in pipelines],
                            placement=placement, work_stealing=work_stealing)
    key_of: dict[int, tuple] = {}
    submitted: set[tuple] = set()

    def submit_ready():
        ready = [key for key, st in remaining.items()
                 if key not in submitted
                 and all((key[0], d) in results for d in st.deps)]
        descs = []
        for key in ready:
            st = remaining[key]
            dep_vals = tuple(results[(key[0], d)] for d in st.deps)
            descs.append(TaskDescription(
                name=f"{key[0]}.{st.name}", ranks=st.ranks, fn=st.fn,
                args=dep_vals, kwargs=st.kwargs, mesh_axes=st.mesh_axes,
                priority=st.priority, duration_model=st.duration_model,
                tags={"pipeline": key[0]}))
        for key, task in zip(ready, sess.submit(descs), strict=True):
            key_of[task.uid] = key
            submitted.add(key)

    submit_ready()
    while remaining:
        if not sess.outstanding:
            raise RuntimeError("dependency cycle or failed deps: "
                               f"{sorted(remaining)}")
        finished = sess.wait_any(timeout=timeout)
        if not finished:
            sess.close()
            raise RuntimeError(
                f"pipelines stalled (timeout or insufficient resources); "
                f"unfinished stages: {sorted(remaining)}")
        for task in finished:
            key = key_of[task.uid]
            if task.state != TaskState.DONE:
                sess.close()
                raise RuntimeError(f"stage {key} failed: {task.error}")
            results[key] = task.result
            del remaining[key]
        submit_ready()
    report: SimReport = sess.close()
    return results, report
