"""MPMD pipelines: DAGs of SPMD tasks (the paper's 'traced program comprising
multiple computations').

A Pipeline is a set of named stages with dependencies; ready stages are
released to the scheduler as their inputs complete, so independent branches
execute concurrently on the shared pool (paper §4.4: 'identifying independent
branches of execution and executing such independent tasks parallelly').
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

from repro.core.scheduler import HETEROGENEOUS, LiveScheduler
from repro.core.task import TaskDescription, TaskState


@dataclasses.dataclass
class Stage:
    name: str
    ranks: int
    fn: Callable            # fn(comm, *dep_results, **kwargs)
    deps: tuple = ()
    kwargs: dict = dataclasses.field(default_factory=dict)
    mesh_axes: tuple = ("df",)
    pipeline: str = "default"


class Pipeline:
    def __init__(self, name: str = "pipeline"):
        self.name = name
        self.stages: dict[str, Stage] = {}

    def add(self, name: str, ranks: int, fn: Callable, deps: Sequence[str] = (),
            **kwargs) -> "Pipeline":
        assert name not in self.stages
        for d in deps:
            assert d in self.stages, f"unknown dep {d}"
        self.stages[name] = Stage(name=name, ranks=ranks, fn=fn,
                                  deps=tuple(deps), kwargs=kwargs,
                                  pipeline=self.name)
        return self

    def topo_order(self) -> list[str]:
        order, seen = [], set()

        def visit(n):
            if n in seen:
                return
            for d in self.stages[n].deps:
                visit(d)
            seen.add(n)
            order.append(n)

        for n in self.stages:
            visit(n)
        return order


def run_pipelines(pipelines: Sequence[Pipeline], resource_manager,
                  policy: str = HETEROGENEOUS, timeout: float = 600.0):
    """Execute several MPMD pipelines concurrently on one device pool.

    Wave-based dependency release: all stages whose deps are satisfied are
    submitted together; the scheduler interleaves stages from different
    pipelines (the heterogeneous-execution win of the paper)."""
    results: dict[tuple, Any] = {}
    remaining = {(p.name, s): p.stages[s] for p in pipelines for s in p.stages}
    sched = LiveScheduler(resource_manager, policy)
    reports = []

    while remaining:
        ready = [key for key, st in remaining.items()
                 if all((key[0], d) in results for d in st.deps)]
        if not ready:
            raise RuntimeError("dependency cycle or failed deps")
        descs = []
        for key in ready:
            st = remaining[key]
            dep_vals = [results[(key[0], d)] for d in st.deps]
            descs.append(TaskDescription(
                name=f"{key[0]}.{st.name}", ranks=st.ranks, fn=st.fn,
                args=tuple(dep_vals), kwargs=st.kwargs,
                mesh_axes=st.mesh_axes, tags={"pipeline": key[0]}))
        rep = sched.run(descs, timeout=timeout)
        reports.append(rep)
        for key, task in zip(ready, rep.tasks):
            if task.state != TaskState.DONE:
                raise RuntimeError(f"stage {key} failed: {task.error}")
            results[key] = task.result
            del remaining[key]
    return results, reports
