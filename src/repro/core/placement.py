"""Topology-aware placement: where a task's ranks land, not just how many.

The paper's heterogeneous runtime keeps devices busy across pipelines, but
WHICH devices a task gets matters as much as how many: a ProcessExecutor
task whose ranks straddle worker processes pays for every collective through
the parent hub, while the same task packed into one worker runs on a single
local sub-mesh and never touches the hub (the Cylon observation that
communicator-group locality dominates join/sort cost).

Two pieces:

* :class:`Topology` — an executor's locality report, ``node -> [handles]``.
  The virtual executor synthesizes nodes (``SimOptions.devices_per_node``),
  the thread executor is one node, the process executor reports one node per
  worker interpreter.
* :func:`plan` — the placement policy: given ``n``, the free list, a
  topology, and the retry-exclusion set, choose the exact devices.

Policies:

* ``SPREAD`` (default) — the historical flat allocation: first ``n`` free
  devices in pool order, devices in ``exclude`` last.  Bit-for-bit the
  behaviour of ``ResourceManager.allocate`` before the placement layer
  existed, so every existing schedule reproduces exactly.
* ``PACK`` — minimize the number of distinct nodes.  If any single node can
  host all ``n`` ranks, pick the *best-fit* such node (fewest free devices,
  preferring nodes with enough non-excluded devices); otherwise fill from
  the emptiest-first (largest free count) nodes so the task spans as few
  nodes as possible.

Both policies are exclude-aware: devices a previous attempt failed on are
chosen only when nothing else fits (the scheduler's retry-with-exclusion
contract).
"""
from __future__ import annotations

from typing import Mapping, Optional, Sequence

PACK = "pack"
SPREAD = "spread"
PLACEMENTS = (SPREAD, PACK)


class Topology:
    """Locality report: ordered mapping of node id -> device handles.

    Node ids are opaque strings (worker ids for the process executor,
    synthetic ``n0/n1/...`` for simulated nodes).  A device missing from
    every node is treated as its own single-device node — the conservative
    choice: pack will never co-locate two devices it knows nothing about.
    """

    def __init__(self, nodes: Mapping[str, Sequence]):
        self.nodes: dict[str, tuple] = {k: tuple(v) for k, v in nodes.items()}
        self._node_of = {d: k for k, devs in self.nodes.items() for d in devs}

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    def node_of(self, device) -> Optional[str]:
        """Node id hosting ``device`` (None when unmapped)."""
        return self._node_of.get(device)

    def group(self, devices: Sequence) -> dict:
        """Group ``devices`` by node, preserving order within each node.
        Unmapped devices each become their own synthetic single-device node
        (keys ``?0``, ``?1``, ...)."""
        out: dict[str, list] = {}
        unknown = 0
        for d in devices:
            node = self._node_of.get(d)
            if node is None:
                node = f"?{unknown}"
                unknown += 1
            out.setdefault(node, []).append(d)
        return out

    def __repr__(self):
        inner = ", ".join(f"{k}:{len(v)}" for k, v in self.nodes.items())
        return f"Topology({inner})"


def _exclude_last(devices: Sequence, exclude: set) -> list:
    if not exclude:
        return list(devices)
    return [d for d in devices if d not in exclude] + \
           [d for d in devices if d in exclude]


def plan(n: int, free: Sequence, topology: Optional[Topology] = None,
         policy: Optional[str] = None, exclude: Sequence = ()) -> list:
    """Choose ``n`` devices from ``free`` under ``policy``.

    ``free`` is the pool's free list in its native order and must hold at
    least ``n`` devices (the caller — ``ResourceManager.allocate_placed`` —
    checks under its lock).  Returns the chosen devices, preserving the
    within-node free-list order so schedules stay deterministic.
    """
    policy = policy or SPREAD
    if policy not in PLACEMENTS:
        raise ValueError(
            f"unknown placement policy {policy!r}; expected one of "
            f"{PLACEMENTS}")
    if n > len(free):
        # the pool normally checks under its lock; an elastic retire racing
        # a direct plan() call must fail loudly, not silently under-allocate
        raise ValueError(f"plan: want {n} devices, free list has {len(free)}")
    exclude = set(exclude)
    if policy == SPREAD or topology is None or topology.n_nodes <= 1:
        # the historical flat path (one node degenerates to it as well)
        return _exclude_last(free, exclude)[:n]

    clean = [d for d in free if d not in exclude]
    if exclude and len(clean) >= n:
        # enough untainted devices exist: pack over them EXCLUSIVELY, so
        # excluded devices are chosen only when nothing else fits — the
        # retry-with-exclusion contract outranks packing one extra rank
        return _pack(n, clean, topology, set())
    return _pack(n, free, topology, exclude)


def _pack(n: int, free: Sequence, topology: Topology, exclude: set) -> list:
    groups = topology.group(free)
    # within a node, clean (non-excluded) devices first
    ordered = {node: _exclude_last(devs, exclude)
               for node, devs in groups.items()}
    node_order = {node: i for i, node in enumerate(ordered)}

    def n_clean(node):
        return sum(1 for d in ordered[node] if d not in exclude)

    # 1) best-fit single node: fewest free devices among those that fit,
    #    preferring nodes with n clean devices; ties broken by pool order
    fits = [node for node, devs in ordered.items() if len(devs) >= n]
    if fits:
        def fit_key(node):
            return (n_clean(node) < n, len(ordered[node]), node_order[node])
        return ordered[min(fits, key=fit_key)][:n]

    # 2) spanning: most clean devices first (taint only when unavoidable),
    #    then largest-free so the task touches as few nodes as possible
    chosen: list = []
    for node in sorted(ordered, key=lambda k: (-n_clean(k),
                                               -len(ordered[k]),
                                               node_order[k])):
        take = min(n - len(chosen), len(ordered[node]))
        chosen.extend(ordered[node][:take])
        if len(chosen) == n:
            break
    return chosen
