"""Task abstractions — the Radical-Pilot TaskDescription analogue.

A Task is an SPMD program (Python callable receiving a Communicator) plus its
resource requirements in *ranks* (devices).  The runtime constructs a private
sub-mesh communicator of exactly ``ranks`` devices at launch time and delivers
it to the payload — the JAX-native equivalent of RAPTOR building a private
MPI communicator per task.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
from typing import Any, Callable, Optional

_uid = itertools.count()


class TaskState(enum.Enum):
    NEW = "NEW"
    PENDING = "PENDING"
    RUNNING = "RUNNING"
    DONE = "DONE"
    FAILED = "FAILED"
    CANCELED = "CANCELED"


@dataclasses.dataclass
class TaskDescription:
    """What the user submits (mirrors rp.TaskDescription)."""
    name: str
    ranks: int                                   # devices required
    fn: Callable[..., Any]                       # fn(comm, *args) -> result
    args: tuple = ()
    kwargs: dict = dataclasses.field(default_factory=dict)
    mesh_axes: tuple = ("df",)                   # axis names for the private mesh
    mesh_shape: Optional[tuple] = None           # default: (ranks,)
    priority: int = 0
    max_retries: int = 2
    duration_model: Optional[Callable[[int], float]] = None  # ranks -> seconds (sim)
    tags: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class Task:
    desc: TaskDescription
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))
    state: TaskState = TaskState.NEW
    result: Any = None
    error: Optional[str] = None
    retries: int = 0
    submit_time: float = 0.0
    start_time: float = 0.0
    end_time: float = 0.0
    comm_build_time: float = 0.0     # "overhead" column of paper Table 2
    devices: tuple = ()
    speculative_of: Optional[int] = None   # uid of the task this duplicates
    excluded_devices: set = dataclasses.field(default_factory=set)
    # devices prior attempts failed on; retries avoid them when possible
    placement: str = ""              # policy that placed this task's devices
    # (pack|spread; set by the scheduler at dispatch, recorded on the comm)
    p2p_bytes: int = 0               # bytes the task's collectives moved
    # worker-to-worker (peer data plane; 0 on sim/thread backends)
    hub_calls: int = 0               # parent-hub round-trips the task paid
    spills: int = 0                  # shuffle partitions spilled to disk
    # under the out-of-core path (0 on sim/thread backends)
    p2p_fallbacks: int = 0           # above-threshold payloads that relayed
    # through the hub because a peer channel could not be used
    hub_relay_bytes: int = 0         # real payload bytes the hub relayed for
    # this task's collectives (peer-plane collectives contribute only the
    # tiny PEER_SENT marker; 0 on sim/thread backends)
    raw_coll_bytes: int = 0          # collective bytes shipped with
    # zero-copy raw framing (0 on sim/thread backends)
    shm_bytes: int = 0               # payload bytes moved through same-host
    # shared-memory segments (a subset of p2p_bytes)
    ring_steps: int = 0              # ring-allgather block forwards paid
    ckpt_dir: str = ""               # task-lineage checkpoint dir under the
    # session ckpt root ("" = checkpointing off; set by the scheduler)
    ckpt_attempt: str = ""           # attempt namespace inside ckpt_dir
    # (a<retries> for primaries, s<uid> for speculative twins)
    resumed_from_step: int = 0       # last checkpoint step this attempt
    # restored before running (0 = ran from scratch)
    cache_hit: bool = False          # completed from the result cache
    # without dispatching (REPRO_RESULT_CACHE)
    cache_key: str = ""              # result-cache digest of (fn, args,
    # kwargs, ranks); "" when the payload is uncacheable

    @property
    def run_seconds(self) -> float:
        return self.end_time - self.start_time

    @property
    def wait_seconds(self) -> float:
        return self.start_time - self.submit_time
