"""Private per-task communicators — the JAX analogue of RAPTOR's runtime
MPI_Comm construction.

A Communicator wraps a ``jax.sharding.Mesh`` built over the exact device
subset allocated to one task.  Construction is timed; the paper reports this
as the (constant, ~seconds) RP overhead in Table 2, and benchmarks/
bench_overhead.py reproduces that measurement here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass
class Communicator:
    mesh: Any                     # jax.sharding.Mesh
    devices: tuple
    axes: tuple
    shape: tuple
    build_seconds: float
    uid: str = ""

    @property
    def size(self) -> int:
        return len(self.devices)

    def sub(self, axis: str):
        """Axis size lookup (MPI_Comm_size analogue per axis)."""
        return dict(zip(self.axes, self.shape))[axis]


def _factor_shape(n: int, naxes: int) -> tuple:
    """Default near-square factorization of n ranks into naxes axes."""
    if naxes == 1:
        return (n,)
    shape = []
    rem = n
    for i in range(naxes - 1):
        f = int(round(rem ** (1 / (naxes - i))))
        while f > 1 and rem % f:
            f -= 1
        shape.append(max(f, 1))
        rem //= max(f, 1)
    shape.append(rem)
    return tuple(shape)


def build_communicator(devices, axes=("df",), shape: Optional[tuple] = None,
                       uid: str = "") -> Communicator:
    """Construct the private mesh over ``devices`` (the heterogeneous-runtime
    core: every task gets its own isolated communicator, any size)."""
    from jax.sharding import Mesh

    t0 = time.perf_counter()
    n = len(devices)
    shape = shape or _factor_shape(n, len(axes))
    assert int(np.prod(shape)) == n, (shape, n)
    arr = np.array(devices, dtype=object).reshape(shape)
    mesh = Mesh(arr, axes)
    dt = time.perf_counter() - t0
    return Communicator(mesh=mesh, devices=tuple(devices), axes=tuple(axes),
                        shape=tuple(shape), build_seconds=dt, uid=uid)
