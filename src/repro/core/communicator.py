"""Private per-task communicators — the JAX analogue of RAPTOR's runtime
MPI_Comm construction.

A Communicator wraps a ``jax.sharding.Mesh`` built over the exact device
subset allocated to one task.  Construction is timed; the paper reports this
as the (constant, ~seconds) RP overhead in Table 2, and benchmarks/
bench_overhead.py reproduces that measurement here.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass
class Communicator:
    mesh: Any                     # jax.sharding.Mesh
    devices: tuple
    axes: tuple
    shape: tuple
    build_seconds: float
    uid: str = ""
    placement: str = ""           # policy that placed the devices (pack|
    # spread; "" when allocation bypassed the scheduler's placement layer)
    # comm-stats surface, uniform across backends: an in-process mesh has no
    # cross-process data plane, so both are constants here — ProcTaskComm
    # reports the real counters under the same names
    p2p_bytes: int = 0            # bytes moved worker-to-worker
    hub_calls: int = 0            # parent-hub round-trips paid
    spills: int = 0               # shuffle partitions spilled to disk
    raw_coll_bytes: int = 0       # bytes shipped with zero-copy framing
    shm_bytes: int = 0            # bytes moved through shm segments
    ring_steps: int = 0           # ring-allgather forwards performed
    checkpoint: Any = None        # CheckpointContext the runtime bound for
    # this attempt (None when checkpointing is off) — payloads call
    # comm.checkpoint.save/latest/restore to survive retries

    @property
    def size(self) -> int:
        return len(self.devices)

    @property
    def degenerate_axes(self) -> tuple:
        """Axis names whose extent collapsed to 1 in a multi-rank mesh —
        see :func:`degenerate_axes`."""
        return tuple(self.axes[i] for i in degenerate_axes(self.shape))

    def sub(self, axis: str):
        """Axis size lookup (MPI_Comm_size analogue per axis)."""
        try:
            return dict(zip(self.axes, self.shape, strict=True))[axis]
        except KeyError:
            raise ValueError(
                f"unknown mesh axis {axis!r}; this communicator has axes "
                f"{self.axes}") from None


def _factor_shape(n: int, naxes: int) -> tuple:
    """Default factorization of ``n`` ranks into ``naxes`` axes, largest
    factor first (so any degenerate size-1 factors trail, e.g. prime ``n``
    with ``naxes=2`` gives ``(n, 1)``, never ``(1, n)``).

    A prime or near-prime ``n`` cannot be factored into ``naxes``
    non-trivial axes; the result then contains size-1 axes — a *degenerate*
    mesh that behaves like a lower-dimensional one (collectives over a
    size-1 axis are no-ops).  Callers that care should check
    :func:`degenerate_axes` instead of assuming every axis is usable."""
    if naxes == 1:
        return (n,)
    shape = []
    rem = n
    for i in range(naxes - 1):
        f = int(round(rem ** (1 / (naxes - i))))
        while f > 1 and rem % f:
            f -= 1
        shape.append(max(f, 1))
        rem //= max(f, 1)
    shape.append(rem)
    return tuple(sorted(shape, reverse=True))


def degenerate_axes(shape: tuple) -> tuple:
    """Indices of size-1 axes in a multi-rank mesh shape.

    ``(7, 1)`` -> ``(1,)``: the second axis exists in name only — a
    collective over it is a no-op, so code partitioning work along it gets
    no parallelism.  A genuinely single-rank mesh (total size 1) has no
    usable parallelism on ANY axis, so nothing is flagged: ``(1,)`` and
    ``(1, 1)`` -> ``()``."""
    if int(np.prod(shape)) <= 1:
        return ()
    return tuple(i for i, s in enumerate(shape) if s == 1)


def build_communicator(devices, axes=("df",), shape: Optional[tuple] = None,
                       uid: str = "", placement: str = "") -> Communicator:
    """Construct the private mesh over ``devices`` (the heterogeneous-runtime
    core: every task gets its own isolated communicator, any size).
    ``placement`` records which policy chose the devices (pack/spread) so a
    payload — and the trace consumers — can see how its ranks were laid
    out."""
    from jax.sharding import Mesh

    t0 = time.perf_counter()
    n = len(devices)
    shape = shape or _factor_shape(n, len(axes))
    assert int(np.prod(shape)) == n, (shape, n)
    arr = np.array(devices, dtype=object).reshape(shape)
    mesh = Mesh(arr, axes)
    dt = time.perf_counter() - t0
    return Communicator(mesh=mesh, devices=tuple(devices), axes=tuple(axes),
                        shape=tuple(shape), build_seconds=dt, uid=uid,
                        placement=placement)
