"""RAPTOR-style master/worker facade over the unified scheduler core (paper
Fig. 3/4).

The master receives TaskDescriptions, asks the scheduler core to place them
on the pilot's devices, builds the private communicator per task, and
collects results — i.e. the orchestration flow of the paper in JAX terms:

    client -> PilotManager -> Pilot -> RaptorMaster -> (comm, task) -> result

Live and simulated execution are the SAME ``SchedulerSession`` dispatch/
retry/spec-exec code path; only the executor differs (threads on real JAX
devices vs the deterministic virtual clock).
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.scheduler import (
    HETEROGENEOUS, SchedulerSession, SimOptions, SimReport,
    ThreadExecutor, simulate,
)
from repro.core.task import TaskDescription


class RaptorMaster:
    """Execution master bound to one pilot."""

    def __init__(self, pilot: Pilot, policy: str = HETEROGENEOUS,
                 speculative_factor: Optional[float] = None):
        self.pilot = pilot
        self.policy = policy
        self.speculative_factor = speculative_factor
        self._queue: list[TaskDescription] = []

    def submit(self, desc: TaskDescription):
        self._queue.append(desc)
        return desc

    def submit_many(self, descs: Sequence[TaskDescription]):
        self._queue.extend(descs)

    def run(self, timeout: float = 600.0) -> SimReport:
        """Execute all queued tasks on real devices; returns the report."""
        sess = SchedulerSession(ThreadExecutor(),
                                self.pilot.resource_manager,
                                policy=self.policy,
                                speculative_factor=self.speculative_factor)
        descs, self._queue = self._queue, []
        return sess.run(descs, timeout=timeout)

    def run_simulated(self, opts: Optional[SimOptions] = None) -> SimReport:
        """Execute on the virtual clock (large-scale experiments) — the same
        scheduler core over a VirtualClockExecutor."""
        opts = opts or SimOptions(policy=self.policy,
                                  speculative_factor=self.speculative_factor)
        descs, self._queue = self._queue, []
        return simulate(descs, self.pilot.desc.n_devices, opts)


def session(n_devices: Optional[int] = None, policy: str = HETEROGENEOUS,
            devices=None) -> RaptorMaster:
    """One-call setup: PilotManager -> Pilot -> RaptorMaster."""
    pm = PilotManager(devices=devices)
    n = n_devices or pm.global_rm.total
    pilot = pm.submit_pilot(PilotDescription(n_devices=n))
    return RaptorMaster(pilot, policy)
