"""RAPTOR-style master/worker facade over the schedulers (paper Fig. 3/4).

The master receives TaskDescriptions, asks the scheduler to place them on the
pilot's devices, builds the private communicator per task, and collects
results — i.e. the orchestration flow of the paper in JAX terms:

    client -> PilotManager -> Pilot -> RaptorMaster -> (comm, task) -> result
"""
from __future__ import annotations

from typing import Optional, Sequence

from repro.core.pilot import Pilot, PilotDescription, PilotManager
from repro.core.scheduler import (
    BATCH, HETEROGENEOUS, LiveScheduler, SimOptions, SimReport, simulate,
)
from repro.core.task import TaskDescription


class RaptorMaster:
    """Execution master bound to one pilot."""

    def __init__(self, pilot: Pilot, policy: str = HETEROGENEOUS):
        self.pilot = pilot
        self.policy = policy
        self._queue: list[TaskDescription] = []

    def submit(self, desc: TaskDescription):
        self._queue.append(desc)
        return desc

    def submit_many(self, descs: Sequence[TaskDescription]):
        self._queue.extend(descs)

    def run(self, timeout: float = 600.0) -> SimReport:
        """Execute all queued tasks on real devices; returns the report."""
        sched = LiveScheduler(self.pilot.resource_manager, self.policy)
        descs, self._queue = self._queue, []
        return sched.run(descs, timeout=timeout)

    def run_simulated(self, opts: Optional[SimOptions] = None) -> SimReport:
        """Execute on the virtual clock (large-scale experiments)."""
        opts = opts or SimOptions(policy=self.policy)
        descs, self._queue = self._queue, []
        return simulate(descs, self.pilot.desc.n_devices, opts)


def session(n_devices: Optional[int] = None, policy: str = HETEROGENEOUS,
            devices=None) -> RaptorMaster:
    """One-call setup: PilotManager -> Pilot -> RaptorMaster."""
    pm = PilotManager(devices=devices)
    n = n_devices or pm.global_rm.total
    pilot = pm.submit_pilot(PilotDescription(n_devices=n))
    return RaptorMaster(pilot, policy)
