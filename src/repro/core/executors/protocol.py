"""Length-prefixed frame protocol between the pilot (parent) and its worker
processes.

A frame is ``>I`` big-endian byte length followed by a stdlib-pickled
``(kind, data)`` tuple where ``data`` is a plain dict of control fields.
User payloads (functions, results) travel inside frames as opaque ``bytes``
produced by ``serialize.dumps`` — the framing layer never unpickles them.

Message kinds
=============
Every task-scoped frame carries (uid, attempt): the scheduler reuses a
task's uid across retries, and the attempt id keeps stale frames from a
failed attempt out of its successor.

worker -> parent:
  HELLO      {worker, pid, n_devices, platform,
              data_host, data_port}                    registration; the
              data address is the worker's peer-data listener (None when
              the peer plane is disabled) — the parent's address book
  HEARTBEAT  {worker, t}                               liveness
  PART_DONE  {uid, attempt, part, result: bytes|None, error: str|None,
              comm_build_s, p2p_bytes, hub_calls,
              p2p_fallbacks}                           one part finished
  COLL       {uid, attempt, seq, part, payload: bytes} collective contribution

parent -> worker:
  LAUNCH     {uid, attempt, name, part, n_parts, local_devices: [int],
              global_ranks: [int], world_size, payload: bytes,
              mesh_axes, mesh_shape, build_comm,
              peer_addrs: [(worker, host, port)|None],
              p2p_threshold}                           run one task part;
              peer_addrs is the full address book of the task's parts so
              large collective payloads can move worker-to-worker
  COLL_RESULT {uid, attempt, seq, values: [bytes]}     gathered contributions
  COLL_ERROR {uid, attempt, seq|None, error}           participant died
  CANCEL     {uid, attempt}                            cooperative abort
  PEERS_UPDATE {workers: {worker: (host, port)|None},
              removed: [worker]}                       refreshed peer address
              book after an elastic grow/retire/loss; a worker closes and
              evicts its cached peer channel to every ``removed`` id
              immediately instead of discovering the dead channel per
              payload (the hub-fallback path)
  SHUTDOWN   {}                                        clean exit

worker -> worker (peer data plane, same framing on the data port):
  PEER_HELLO {worker, token}                           authenticate channel
  PEER_DATA  {uid, attempt, seq, part, payload: bytes} one part's collective
              payload, shipped directly to a peer — the hub sees only the
              PEER_SENT placeholder for it
"""
from __future__ import annotations

import pickle
import socket
import struct
import threading

HELLO = "hello"
HEARTBEAT = "heartbeat"
PART_DONE = "part_done"
COLL = "coll"
LAUNCH = "launch"
COLL_RESULT = "coll_result"
COLL_ERROR = "coll_error"
CANCEL = "cancel"
PEERS_UPDATE = "peers_update"
SHUTDOWN = "shutdown"
PEER_HELLO = "peer_hello"
PEER_DATA = "peer_data"

#: Placeholder a part sends the hub instead of its payload when the payload
#: already went worker-to-worker over the peer data plane.  Real payloads are
#: ``serialize.dumps`` output — a pickle stream, which always opens with the
#: b"\x80" PROTO opcode — so a value starting with b"\x00" can never collide.
PEER_SENT = b"\x00p2p\x00"

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 31   # 2 GiB sanity cap


class ConnectionClosed(Exception):
    """Peer went away (EOF or reset) — the liveness signal for SIGKILL."""


class Channel:
    """One framed, thread-safe duplex connection.

    Sends may come from several threads (scheduler launch, hub replies,
    heartbeat) and are serialized by a lock; receives are single-threaded
    (each side owns one reader loop).  ``on_traffic`` (if set) fires per
    received chunk — heartbeats queue BEHIND a large in-flight frame on the
    same TCP stream, so byte progress itself must count as liveness."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._send_lock = threading.Lock()
        self.on_traffic = None

    def send(self, kind: str, **data):
        frame = pickle.dumps((kind, data), protocol=pickle.HIGHEST_PROTOCOL)
        with self._send_lock:
            try:
                self.sock.sendall(_LEN.pack(len(frame)) + frame)
            except OSError as e:
                raise ConnectionClosed(str(e)) from e

    def _recv_exact(self, n: int) -> bytes:
        chunks = []
        while n:
            try:
                chunk = self.sock.recv(min(n, 1 << 20))
            except OSError as e:
                raise ConnectionClosed(str(e)) from e
            if not chunk:
                raise ConnectionClosed("EOF")
            if self.on_traffic is not None:
                self.on_traffic()
            chunks.append(chunk)
            n -= len(chunk)
        return b"".join(chunks)

    def recv(self):
        """Blocking read of the next ``(kind, data)`` frame."""
        (n,) = _LEN.unpack(self._recv_exact(_LEN.size))
        if n > MAX_FRAME:
            raise ConnectionClosed(f"oversized frame ({n} bytes)")
        return pickle.loads(self._recv_exact(n))

    def close(self):
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
