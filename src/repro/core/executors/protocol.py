"""Length-prefixed frame protocol between the pilot (parent) and its worker
processes.

A frame is ``>I`` big-endian byte length followed by a stdlib-pickled
``(kind, data)`` tuple where ``data`` is a plain dict of control fields.
User payloads (functions, results) travel inside frames as opaque ``bytes``
produced by ``serialize.dumps`` — the framing layer never unpickles them.

Message kinds
=============
Every task-scoped frame carries (uid, attempt): the scheduler reuses a
task's uid across retries, and the attempt id keeps stale frames from a
failed attempt out of its successor.

worker -> parent:
  HELLO      {worker, pid, n_devices, platform,
              data_host, data_port, perf_t}            registration; the
              data address is the worker's peer-data listener (None when
              the peer plane is disabled) — the parent's address book.
              perf_t is the worker's perf_counter stamped at send time:
              the parent derives this worker's clock offset from it, the
              alignment every shipped span/telemetry timestamp rides on
  HEARTBEAT  {worker, t, perf_t, telemetry}            liveness + the
              worker's gauge/counter snapshot (queue depth, RSS, spill
              bytes, peer channels, p2p_fallbacks) — the parent surfaces
              it as a ``telemetry`` trace event at perf_t + clock offset
  PART_DONE  {uid, attempt, part, result: bytes|None, error: str|None,
              comm_build_s, p2p_bytes, hub_calls,
              p2p_fallbacks, spills,
              spans: [(kind, t0, t1), ...]}            one part finished;
              spans are the part's flight-recorder sections in the
              worker's clock, aligned and merged into the trace by the
              parent
  COLL       {uid, attempt, seq, part, payload: bytes} collective contribution

parent -> worker:
  LAUNCH     {uid, attempt, name, part, n_parts, local_devices: [int],
              global_ranks: [int], world_size, payload: bytes,
              mesh_axes, mesh_shape, build_comm,
              peer_addrs: [(worker, host, port)|None],
              p2p_threshold, raw_frames}               run one task part;
              peer_addrs is the full address book of the task's parts so
              large collective payloads can move worker-to-worker
  COLL_RESULT {uid, attempt, seq, values: [bytes]}     gathered contributions
  COLL_ERROR {uid, attempt, seq|None, error}           participant died
  CANCEL     {uid, attempt}                            cooperative abort
  PEERS_UPDATE {workers: {worker: (host, port)|None},
              removed: [worker]}                       refreshed peer address
              book after an elastic grow/retire/loss; a worker closes and
              evicts its cached peer channel to every ``removed`` id
              immediately instead of discovering the dead channel per
              payload (the hub-fallback path)
  SHUTDOWN   {}                                        clean exit

worker -> worker (peer data plane, same framing on the data port):
  PEER_HELLO {worker, token}                           authenticate channel
  PEER_DATA  {uid, attempt, seq, part, payload: bytes} one part's collective
              payload, shipped directly to a peer — the hub sees only the
              PEER_SENT placeholder for it
  PEER_DATA_RAW {uid, attempt, seq, part, nbytes,
              cols: [(name, dtype, shape), ...]}       raw-buffer framing:
              the pickled header above is followed by ``nbytes`` of raw
              array bytes ON THE SAME STREAM (the columns' contiguous
              buffers, concatenated in ``cols`` order).  The payload never
              passes through pickle on either side — the sender writes the
              arrays' memoryviews straight to the socket and the receiver
              reconstructs zero-copy views with ``np.frombuffer`` — which
              is what makes MB-scale shuffle buckets cheap to ship.
  PEER_DATA_GEN {uid, attempt, seq, part, nbytes,
              skel: bytes, arrs: [(dtype, shape), ...]} generic raw-buffer
              framing for ANY collective payload (allgather/bcast bodies,
              not just shuffle column dicts): ``skel`` is the pickled
              container skeleton with array leaves replaced by indexed
              placeholders (``serialize.dumps_arrays``), ``arrs`` the
              leaves' dtype/shape metadata, and ``nbytes`` of raw leaf
              bytes follow the header on the stream exactly like
              PEER_DATA_RAW.
  PEER_DATA_SHM {uid, attempt, seq, part, nbytes, shm,
              skel: bytes|None, arrs: list|None}        same-host handoff:
              the body bytes live in the named tmpfs segment file ``shm``
              (see ``executors.shm``) — only this header travels on the
              socket.  ``skel``/``arrs`` carry the generic raw layout
              (``skel is None`` means the segment holds one pickled
              payload).  The RECEIVER unlinks the segment after copying
              it out; unconsumed segments are unlinked by the sender's
              purge or swept by the parent (worker death).
"""
from __future__ import annotations

import pickle
import socket
import struct
import threading

HELLO = "hello"
HEARTBEAT = "heartbeat"
PART_DONE = "part_done"
COLL = "coll"
LAUNCH = "launch"
COLL_RESULT = "coll_result"
COLL_ERROR = "coll_error"
CANCEL = "cancel"
PEERS_UPDATE = "peers_update"
SHUTDOWN = "shutdown"
PEER_HELLO = "peer_hello"
PEER_DATA = "peer_data"
PEER_DATA_RAW = "peer_data_raw"
PEER_DATA_GEN = "peer_data_gen"
PEER_DATA_SHM = "peer_data_shm"

#: frame kinds whose pickled header is followed by ``nbytes`` of raw body
#: bytes on the same stream (read by ``Channel.recv`` into ``payload``).
#: PEER_DATA_SHM is deliberately NOT here: its body never touches the
#: socket — it lives in the named shared-memory segment.
RAW_BODY_KINDS = frozenset({PEER_DATA_RAW, PEER_DATA_GEN})

#: Placeholder a part sends the hub instead of its payload when the payload
#: already went worker-to-worker over the peer data plane.  Real payloads are
#: ``serialize.dumps`` output — a pickle stream, which always opens with the
#: b"\x80" PROTO opcode — so a value starting with b"\x00" can never collide.
PEER_SENT = b"\x00p2p\x00"

_LEN = struct.Struct(">I")
MAX_FRAME = 1 << 31   # 2 GiB sanity cap


class ConnectionClosed(Exception):
    """Peer went away (EOF or reset) — the liveness signal for SIGKILL."""


class Channel:
    """One framed, thread-safe duplex connection.

    Sends may come from several threads (scheduler launch, hub replies,
    heartbeat) and are serialized by a lock; receives are single-threaded
    (each side owns one reader loop).  ``on_traffic`` (if set) fires per
    received chunk — heartbeats queue BEHIND a large in-flight frame on the
    same TCP stream, so byte progress itself must count as liveness."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._send_lock = threading.Lock()
        self.on_traffic = None

    def send(self, kind: str, **data):
        frame = pickle.dumps((kind, data), protocol=pickle.HIGHEST_PROTOCOL)
        with self._send_lock:
            try:
                self.sock.sendall(_LEN.pack(len(frame)) + frame)
            except OSError as e:
                raise ConnectionClosed(str(e)) from e

    def send_raw(self, kind: str, bufs, **data):
        """Send a raw-body frame: the pickled ``(kind, data)`` header (with
        ``nbytes`` filled in) followed by every buffer in ``bufs`` written
        straight to the socket — no pickle round-trip for the body.  The
        buffers must stay alive/unmutated for the duration of the call;
        ``kind`` must be in :data:`RAW_BODY_KINDS` so the receiver knows to
        read the body."""
        views = [memoryview(b).cast("B") for b in bufs]
        data["nbytes"] = sum(v.nbytes for v in views)
        frame = pickle.dumps((kind, data), protocol=pickle.HIGHEST_PROTOCOL)
        with self._send_lock:
            try:
                self.sock.sendall(_LEN.pack(len(frame)) + frame)
                for v in views:
                    self.sock.sendall(v)
            except OSError as e:
                raise ConnectionClosed(str(e)) from e

    def _recv_exact(self, n: int) -> bytes:
        chunks = []
        while n:
            try:
                chunk = self.sock.recv(min(n, 1 << 20))
            except OSError as e:
                raise ConnectionClosed(str(e)) from e
            if not chunk:
                raise ConnectionClosed("EOF")
            if self.on_traffic is not None:
                self.on_traffic()
            chunks.append(chunk)
            n -= len(chunk)
        return b"".join(chunks)

    def recv(self):
        """Blocking read of the next ``(kind, data)`` frame.  A raw-body
        frame's trailing bytes are read off the stream here and attached as
        ``data["payload"]`` — the framing stays self-delimiting either way."""
        (n,) = _LEN.unpack(self._recv_exact(_LEN.size))
        if n > MAX_FRAME:
            raise ConnectionClosed(f"oversized frame ({n} bytes)")
        kind, data = pickle.loads(self._recv_exact(n))
        if kind in RAW_BODY_KINDS:
            nbytes = data.get("nbytes", 0)
            if nbytes > MAX_FRAME:
                raise ConnectionClosed(f"oversized raw body ({nbytes} bytes)")
            data["payload"] = self._recv_exact(nbytes)
        return kind, data

    def close(self):
        try:
            self.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        try:
            self.sock.close()
        except OSError:
            pass
