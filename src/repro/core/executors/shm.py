"""Same-host shared-memory payload handoff — the fastest transport tier.

When two workers of a spanning task advertise the SAME host in the peer
address book, a collective payload's body does not need the socket at all:
the sender writes it into a tmpfs-backed segment file under ``/dev/shm``
and ships only the segment name + layout header as a PEER_DATA_SHM frame;
the receiver reads it back and unlinks.  One kernel copy per side, no TCP
stack, no per-chunk socket syscalls — the same reason the Cylon line of
work leans on buffer-level local transports before hitting the wire.

Why plain files instead of ``multiprocessing.shared_memory``: a fresh
``shm_open`` + ``mmap`` per payload pays a minor page fault for every 4 KiB
touched on BOTH sides, which measures ~3.5x SLOWER than loopback TCP at
1 MiB; ``write()``/``read()`` on the same tmpfs keeps the copies in the
kernel (no faulting, no mmap churn, no resource_tracker to fight) and beats
the socket.  Same mount, same lifetime semantics, simpler cleanup.

Cleanup is a protocol, not a hope — a segment file outlives its creator,
so every path must account for it:

* **consume** — the receiver unlinks right after reading (normal case);
* **purge** — a parked-but-unclaimed frame (attempt ended first) is
  unlinked by the mailbox purge; an ABORTED sender unlinks every segment
  it created for the attempt (``_PeerNet`` keeps the per-attempt ledger);
* **sweep** — the parent removes ``/dev/shm`` residue by name prefix after
  a worker is SIGKILLed/retired and at shutdown.  Segment names embed the
  pilot token and the CREATOR's worker id (``repro_{tok8}_{wid}_{pid}_{n}``)
  precisely so the parent can target a dead worker's leftovers — the one
  cleanup no worker can perform for itself after SIGKILL.
"""
from __future__ import annotations

import itertools
import os
from pathlib import Path

SHM_DIR = Path("/dev/shm")
HAVE_SHM = os.name == "posix" and SHM_DIR.is_dir()

_counter = itertools.count()


def segment_name(token: str, worker_id: str) -> str:
    """A host-unique segment name carrying the sweep handles: pilot token
    prefix (shutdown sweep) and creator worker id (death/retire sweep)."""
    return (f"repro_{(token or 'anon')[:8]}_{worker_id}_"
            f"{os.getpid()}_{next(_counter)}")


def write(name: str, bufs) -> int:
    """Write the payload body (an iterable of buffers) into segment
    ``name``; returns the byte count.  Raises OSError when /dev/shm is
    full or unusable — the caller drops to the next tier."""
    total = 0
    with open(SHM_DIR / name, "wb") as f:
        for b in bufs:
            total += f.write(b)
    return total


def read(name: str) -> bytes:
    """The segment's body (raises FileNotFoundError when it was already
    reclaimed — e.g. the attempt aborted and the sender purged)."""
    with open(SHM_DIR / name, "rb") as f:
        return f.read()


def unlink(name: str) -> bool:
    """Best-effort removal of a segment by name; True when it existed."""
    try:
        os.unlink(SHM_DIR / name)
        return True
    except (FileNotFoundError, OSError):
        return False


def sweep(prefix: str) -> int:
    """Unlink every ``/dev/shm`` entry starting with ``prefix`` — the
    parent-side safety net for segments whose creator died before the
    header (and thus the cleanup obligation) reached any receiver.  Returns
    the number removed; a no-op on hosts without a /dev/shm mount."""
    if not HAVE_SHM:
        return 0
    n = 0
    for p in SHM_DIR.glob(prefix + "*"):
        try:
            p.unlink()
            n += 1
        except OSError:
            pass
    return n
