"""Executor interface: the seam between the scheduler core (policy, retry,
spec-exec, pool handling) and the mechanics of actually running one task.

Three backends live behind this interface:

* ``VirtualClockExecutor`` (``virtual.py``) — deterministic event heap.
* ``ThreadExecutor`` (``thread.py``) — worker threads in this process.
* ``ProcessExecutor`` (``proc.py``) — one fresh interpreter per "node",
  devices spanning processes, heartbeat liveness (the paper's multi-node
  pilot runtime).
"""
from __future__ import annotations

import abc
import dataclasses
import queue as _queue
import time as _time
from typing import Any, Optional

from repro.core.task import Task


@dataclasses.dataclass
class ExecEvent:
    """What an executor delivers back to the scheduler core."""
    kind: str        # done|fail|tick|device_failure|grow|retire|telemetry
    task: Optional[Task] = None
    result: Any = None
    error: Optional[str] = None
    comm_build_s: float = 0.0
    p2p_bytes: int = 0             # bytes the task's collectives moved
    # worker-to-worker (process executor's peer data plane; identically 0
    # on the in-process and virtual backends — uniform trace evidence)
    hub_calls: int = 0             # parent-hub round-trips the task paid
    spills: int = 0                # shuffle partitions the task spilled to
    # disk (out-of-core shuffle evidence; 0 on sim/thread backends)
    p2p_fallbacks: int = 0         # above-threshold payloads that fell back
    # to the hub relay (peer channel unusable)
    hub_relay_bytes: int = 0       # real payload bytes the hub relayed for
    # the task's collectives (control-only PEER_SENT frames excluded)
    raw_coll_bytes: int = 0        # collective bytes shipped with zero-copy
    # raw framing (generic raw frames + raw-layout shm segments)
    shm_bytes: int = 0             # payload bytes handed to same-host peers
    # through shared-memory segments (a subset of p2p_bytes)
    ring_steps: int = 0            # ring-allgather block forwards performed
    resumed_from_step: int = 0     # checkpoint step the attempt restored
    # before running (crash-safe resume evidence; 0 = ran from scratch,
    # max over a multi-part proc task's workers)
    spans: list = dataclasses.field(default_factory=list)   # worker-side
    # flight-recorder spans of a terminal event, already aligned into the
    # parent clock: [{kind, t0, t1, worker, part, uid, task}, ...]; empty
    # on sim/thread backends — same schema, empty section
    worker: str = ""               # telemetry: reporting worker id
    telemetry: Optional[dict] = None   # telemetry: the gauge/counter
    # snapshot a HEARTBEAT frame carried (queue depth, RSS, spill bytes,
    # peer channels, p2p_fallbacks), aligned timestamp under "t"
    n_devices: int = 0             # device_failure/grow/retire payload
    devices: tuple = ()            # device_failure/retire: the EXACT devices
    # lost or retired (empty -> the core shrinks the pool by n_devices
    # arbitrary free devices, the virtual-clock injection semantics;
    # non-empty -> those specific handles leave wherever they are, busy or
    # free — how a process executor reports a crashed or retired worker's
    # inventory).  grow: the EXACT devices joining the pool (empty -> the
    # core invents n_devices fresh handles, again the virtual-clock case)


class Executor(abc.ABC):
    """Runs one task at a time on behalf of the scheduler core.

    The core allocates ``task.devices`` from the policy pools, then calls
    ``launch``; the executor later delivers exactly one ``done``/``fail``
    ExecEvent per launch via ``poll`` (unless ``cancel`` returned True).
    The executor also owns the clock: virtual seconds or wall time.
    """

    #: True when ``now()`` is wall time.  Scheduler timeouts are liveness
    #: guards against hangs, so they are enforced only on wall-clock
    #: executors — a virtual clock drains its event heap deterministically
    #: and healthy simulations routinely span thousands of virtual seconds.
    wall_clock: bool = True

    @abc.abstractmethod
    def now(self) -> float:
        ...

    @abc.abstractmethod
    def launch(self, task: Task, duration_hint: Optional[float] = None):
        """Begin executing ``task`` on ``task.devices``.  ``duration_hint``
        is set for speculative duplicates (expected runtime on a healthy
        device); the virtual clock honours it, live executors ignore it."""

    @abc.abstractmethod
    def poll(self, timeout: Optional[float]) -> Optional[ExecEvent]:
        """Next event.  ``timeout == 0`` -> non-blocking (None if nothing is
        ready *right now*; must not advance a virtual clock).  Otherwise a
        live executor blocks up to ``timeout`` and returns a ``tick`` event
        on expiry; a virtual executor returns the next event (advancing its
        clock) or None when no event can ever arrive again."""

    def cancel(self, task: Task) -> bool:
        """Best-effort abort.  True -> the task is dead *now* and no event
        will be delivered for it (core reclaims devices immediately).
        False -> a completion event will still arrive later (live threads
        cannot be killed; the core ignores the event and reclaims then)."""
        return False

    def topology(self, devices):
        """Locality report for ``devices``: a ``placement.Topology`` grouping
        the handles by the node that hosts them.  Placement policies (pack /
        spread) consult it so a task's ranks can be kept on one node.

        Default: everything on one node — correct for in-process executors
        (``ThreadExecutor``), where every device shares an address space.
        ``ProcessExecutor`` reports one node per worker interpreter;
        ``VirtualClockExecutor`` synthesizes nodes per
        ``SimOptions.devices_per_node``."""
        from repro.core.placement import Topology
        return Topology({"node0": tuple(devices)})


class QueueEventExecutor(Executor):
    """Shared wall-clock plumbing for live executors: completion events are
    pushed onto ``self._q`` from worker threads (or socket readers) and
    drained by ``poll`` with the tick-on-timeout contract the scheduler core
    expects.  Subclasses set ``self.tick`` and call ``super().__init__()``.
    """

    def __init__(self):
        self._q: "_queue.Queue[ExecEvent]" = _queue.Queue()

    def now(self) -> float:
        return _time.perf_counter()

    def poll(self, timeout: Optional[float]) -> Optional[ExecEvent]:
        if timeout == 0:
            try:
                return self._q.get_nowait()
            except _queue.Empty:
                return None
        try:
            return self._q.get(timeout=self.tick if timeout is None
                               else min(timeout, self.tick))
        except _queue.Empty:
            return ExecEvent("tick")

    # -- elastic pool injection --------------------------------------------
    # Any wall-clock executor can hand new device handles to (or withdraw
    # free ones from) the scheduler core at runtime: the core absorbs the
    # event on its next poll, mutates the pool, emits the matching
    # ``grow``/``retire`` trace event, and immediately re-dispatches pending
    # work.  ``ProcessExecutor.add_worker``/``retire_worker`` are the
    # full-stack variants (they spawn/drain a worker process around the same
    # injection); ``ThreadExecutor`` users call these directly.
    def inject_grow(self, devices):
        devices = tuple(devices)
        self._q.put(ExecEvent("grow", n_devices=len(devices),
                              devices=devices))

    def inject_retire(self, devices):
        devices = tuple(devices)
        self._q.put(ExecEvent("retire", n_devices=len(devices),
                              devices=devices))
