"""Executor backends behind the unified scheduler core.

* ``VirtualClockExecutor`` — deterministic event heap (paper-scale sims).
* ``ThreadExecutor`` — worker threads on this process's JAX devices.
* ``ProcessExecutor`` — one fresh interpreter per node, devices spanning
  processes, wire-protocol task shipping, heartbeat liveness (the paper's
  distributed pilot runtime).

``repro.core.scheduler`` re-exports all of these, so historical imports
(``from repro.core.scheduler import ThreadExecutor``) keep working.
"""
from repro.core.executors.base import ExecEvent, Executor
from repro.core.executors.proc import ProcDevice, ProcessExecutor
from repro.core.executors.thread import StubComm, ThreadExecutor
from repro.core.executors.virtual import (
    SimOptions, VirtualClockExecutor, default_overhead_model,
)

__all__ = [
    "ExecEvent", "Executor", "ProcDevice", "ProcessExecutor", "SimOptions",
    "StubComm", "ThreadExecutor", "VirtualClockExecutor",
    "default_overhead_model",
]
