"""Deterministic event-heap executor — the paper's large-scale simulation
mode (84–2688 ranks in milliseconds)."""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
from typing import Callable, Optional, Sequence

from repro.core.executors.base import ExecEvent, Executor
from repro.core.task import Task


# ---------------------------------------------------------------------------
# calibrated models (defaults measured on this container; see
# benchmarks/bench_overhead.py which re-measures and can override)
# ---------------------------------------------------------------------------
def default_overhead_model(ranks: int) -> float:
    """Communicator-construction + task-description overhead (seconds).
    The paper's Table 2 reports 2.3-3.5 s, roughly flat in ranks; our JAX
    sub-mesh build is milliseconds, so the sim uses the paper-calibrated
    constants to reproduce Table 2, while bench_overhead.py reports our own
    measured numbers."""
    return 2.8 + 0.0012 * ranks


@dataclasses.dataclass
class SimOptions:
    policy: str = "heterogeneous"
    overhead_model: Callable[[int], float] = default_overhead_model
    noise: float = 0.02                  # lognormal sigma on durations
    seed: int = 0
    straggler_prob: float = 0.0          # chance a task runs slow
    straggler_slowdown: float = 3.0
    speculative_factor: Optional[float] = None   # e.g. 1.5 -> spec-exec on
    failure_prob: float = 0.0            # chance a task attempt fails
    device_failures: Sequence[tuple] = ()  # [(time_s, n_devices), ...]
    grow_at: Sequence[tuple] = ()        # [(time_s, n_devices), ...]: elastic
    # grow — the core invents fresh handles and backfills pending work, so
    # elastic scenarios (paper: pilot resize mid-run) replay deterministically
    retire_at: Sequence[tuple] = ()      # [(time_s, n_devices), ...]: graceful
    # shrink — up to n free devices leave the pool (busy ones stay with
    # their tasks; the executor-level analogue is ProcessExecutor.retire_worker)
    placement: str = "spread"            # pack|spread (see core/placement.py)
    work_stealing: bool = False          # BATCH: lease idle partition devices
    devices_per_node: int = 0            # synthetic topology: devices per
    # simulated node (0 -> the whole pool is one node, topology-blind)
    ckpt_period_s: float = 0.0           # model payloads checkpointing every
    # N virtual seconds: a failed attempt banks its durable progress
    # (floored to whole periods) and the retry runs only the remainder,
    # reporting resumed_from_step — the sim analogue of CheckpointContext.
    # Takes effect only for tasks launched with a checkpoint namespace
    # (session ckpt_root/REPRO_CKPT_DIR), mirroring the live backends.
    # 0 -> retries re-run from scratch (the historical behaviour)


class VirtualClockExecutor(Executor):
    """Deterministic event-heap executor — the paper's large-scale mode.

    Durations come from ``desc.duration_model(ranks)`` with lognormal noise,
    straggler and failure injection per ``SimOptions``; communicator-build
    overhead from ``opts.overhead_model``.  Device failures are injected as
    timed events the core turns into pool shrinks."""

    wall_clock = False

    def __init__(self, opts: Optional[SimOptions] = None):
        import random
        self.opts = opts or SimOptions()
        self.rng = random.Random(self.opts.seed)
        self._now = 0.0
        self._seq = itertools.count()
        self._heap: list = []
        self._canceled: set = set()
        self._ckpt_progress: dict = {}   # primary uid -> durable virtual
        # seconds banked by failed attempts (ckpt_period_s resume model)
        for ft, nf in self.opts.device_failures:
            heapq.heappush(self._heap,
                           (ft, next(self._seq),
                            ExecEvent("device_failure", n_devices=nf)))
        for gt, ng in self.opts.grow_at:
            heapq.heappush(self._heap,
                           (gt, next(self._seq),
                            ExecEvent("grow", n_devices=ng)))
        for rt, nr in self.opts.retire_at:
            heapq.heappush(self._heap,
                           (rt, next(self._seq),
                            ExecEvent("retire", n_devices=nr)))

    def now(self) -> float:
        return self._now

    def launch(self, task: Task, duration_hint: Optional[float] = None):
        opts = self.opts
        if duration_hint is not None:
            # speculative duplicate: runs at the hinted (median) rate on a
            # fresh device — no overhead, no straggler/failure injection
            oh, dur, fails = 0.0, duration_hint, False
        else:
            oh = opts.overhead_model(task.desc.ranks)
            dur = task.desc.duration_model(task.desc.ranks)
            dur *= math.exp(self.rng.gauss(0.0, opts.noise))
            if opts.straggler_prob and self.rng.random() < opts.straggler_prob:
                dur *= opts.straggler_slowdown
            fails = bool(opts.failure_prob
                         and self.rng.random() < opts.failure_prob)
        resumed = 0
        period = opts.ckpt_period_s
        if period > 0 and task.ckpt_dir and duration_hint is None:
            # resume model: this attempt restores whatever whole-period
            # progress earlier attempts durably banked, and runs only the
            # remainder.  A spec twin (duration_hint) models a fresh device
            # at the hinted rate and is left alone.
            banked = self._ckpt_progress.get(task.uid, 0.0)
            resumed = int(banked // period)
            dur = max(dur - resumed * period, 0.0)
            if fails:
                # what THIS attempt will have durably saved when it dies
                self._ckpt_progress[task.uid] = \
                    resumed * period + (dur // period) * period
            else:
                self._ckpt_progress.pop(task.uid, None)
        ev = ExecEvent("fail" if fails else "done", task=task,
                       error="injected failure" if fails else None,
                       comm_build_s=oh, resumed_from_step=resumed)
        heapq.heappush(self._heap,
                       (self._now + oh + dur, next(self._seq), ev))

    def poll(self, timeout: Optional[float]) -> Optional[ExecEvent]:
        if timeout == 0:
            return None   # never advance the clock on an opportunistic poll
        while self._heap:
            t, _, ev = heapq.heappop(self._heap)
            if ev.task is not None and ev.task.uid in self._canceled:
                continue
            self._now = t
            return ev
        return None

    def cancel(self, task: Task) -> bool:
        self._canceled.add(task.uid)
        return True

    def topology(self, devices):
        """Synthetic nodes: integer device ``d`` lives on node
        ``n{d // devices_per_node}`` — a stable assignment, so the same
        device maps to the same node no matter which subset (e.g. a pool's
        free list) is being classified.  Non-integer handles, or
        ``devices_per_node == 0``, degrade to one flat node (the historical
        topology-blind view)."""
        from repro.core.placement import Topology
        k = self.opts.devices_per_node
        if k <= 0 or not all(isinstance(d, int) for d in devices):
            return Topology({"node0": tuple(devices)})
        nodes: dict = {}
        for d in devices:
            nodes.setdefault(f"n{d // k}", []).append(d)
        return Topology(nodes)
