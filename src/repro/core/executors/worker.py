"""Worker-process entry point for :class:`ProcessExecutor`.

One worker == one "node" of the paper's pilot: a fresh interpreter whose
XLA_FLAGS were set by the parent (``--xla_force_host_platform_device_count=K``)
so it owns K host devices.  The worker

* dials back to the parent, registers its device inventory (HELLO),
* sends HEARTBEAT frames so the scheduler gets real liveness detection,
* runs each LAUNCH frame's task *part* in its own thread: builds the local
  sub-mesh communicator, wraps it in a :class:`ProcTaskComm` (which adds
  cross-process collectives via the parent's hub), calls the payload, and
  ships the serialized result back (PART_DONE).

Run as ``python -m repro.core.executors.worker --addr HOST:PORT ...``.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
from typing import Optional

from repro.core.executors import protocol, serialize
from repro.core.executors.protocol import Channel, ConnectionClosed
from repro.core.executors.thread import StubComm


class CollectiveError(RuntimeError):
    """A collective could not complete (a participant's worker died)."""


class _Hub:
    """Client side of the parent-coordinated collectives: one outstanding
    request per (uid, attempt, seq), answered by COLL_RESULT or COLL_ERROR.
    ``attempt`` keeps a retried task (same uid) from ever being confused
    with frames or abort markers of its failed predecessor."""

    def __init__(self, chan: Channel):
        self.chan = chan
        self._lock = threading.Lock()
        self._waiting: dict = {}   # (uid, attempt, seq) -> [event, values]
        self._dead: dict = {}      # (uid, attempt) -> error (task aborted)

    def call(self, uid: int, attempt: int, seq: int, part: int,
             payload: bytes, timeout: float) -> list:
        with self._lock:
            if (uid, attempt) in self._dead:
                raise CollectiveError(self._dead[(uid, attempt)])
            slot = [threading.Event(), None]
            self._waiting[(uid, attempt, seq)] = slot
        self.chan.send(protocol.COLL, uid=uid, attempt=attempt, seq=seq,
                       part=part, payload=payload)
        if not slot[0].wait(timeout):
            with self._lock:
                self._waiting.pop((uid, attempt, seq), None)
            raise CollectiveError(
                f"collective uid={uid} seq={seq} timed out after {timeout}s")
        if isinstance(slot[1], Exception):
            raise slot[1]
        return slot[1]

    def deliver(self, uid: int, attempt: int, seq: int, values: list):
        with self._lock:
            slot = self._waiting.pop((uid, attempt, seq), None)
        if slot:
            slot[1] = values
            slot[0].set()

    def fail(self, uid: int, attempt: int, seq: Optional[int], error: str):
        with self._lock:
            self._dead[(uid, attempt)] = error
            keys = [k for k in self._waiting
                    if k[:2] == (uid, attempt) and (seq is None or k[2] == seq)]
            for k in keys:
                slot = self._waiting.pop(k)
                slot[1] = CollectiveError(error)
                slot[0].set()

    def forget(self, uid: int, attempt: int):
        """Drop the abort marker once the attempt's part thread has exited —
        a dead attempt never comes back, and without this the marker dict
        grows by one entry per cancelled attempt for the worker's life."""
        with self._lock:
            self._dead.pop((uid, attempt), None)


class ProcTaskComm:
    """The communicator a payload receives under :class:`ProcessExecutor`.

    Mirrors the thread-mode ``Communicator`` surface (``mesh``, ``devices``,
    ``build_seconds``) for the ranks local to THIS worker, and adds the
    cross-process view: ``size`` is the task's total rank count (the paper's
    heterogeneous communicator spanning nodes), ``local_size`` the ranks this
    process owns, and ``allgather``/``bcast``/``barrier`` coordinate all
    parts through the pilot's hub.  Payloads written for ``ThreadExecutor``
    keep working unchanged as long as the task fits one worker (then
    ``size == local_size`` and ``mesh`` covers every rank)."""

    def __init__(self, uid: int, world_size: int, global_ranks: tuple,
                 part: int, n_parts: int, local_comm, hub: _Hub,
                 attempt: int = 0, coll_timeout: float = 120.0,
                 cancelled: Optional[threading.Event] = None,
                 placement: str = ""):
        self.uid = uid
        self.attempt = attempt
        self.world_size = world_size
        self.global_ranks = tuple(global_ranks)
        self.part = part
        self.n_parts = n_parts
        self.local_comm = local_comm
        self.cancelled = cancelled or threading.Event()
        self.placement = placement   # policy that placed this task (pack|
        # spread); under pack a fitting task has n_parts == 1 and its
        # collectives below never touch the hub
        self.hub_calls = 0           # parent-hub round-trips actually paid
        self._hub = hub
        self._seq = 0
        self._coll_timeout = coll_timeout

    # --- Communicator-compatible surface (local ranks) -------------------
    @property
    def mesh(self):
        return self.local_comm.mesh

    @property
    def devices(self) -> tuple:
        return tuple(self.local_comm.devices)

    @property
    def build_seconds(self) -> float:
        return self.local_comm.build_seconds

    @property
    def size(self) -> int:
        """Total ranks of the task across all workers."""
        return self.world_size

    @property
    def local_size(self) -> int:
        return len(self.global_ranks)

    @property
    def rank(self) -> int:
        """First global rank owned by this part."""
        return self.global_ranks[0]

    def sub(self, axis: str):
        return self.local_comm.sub(axis)

    # --- cross-process collectives (per-part granularity) -----------------
    def allgather(self, obj) -> list:
        """Gather one object per *part* (worker share), same list everywhere,
        ordered by part index.  Parts must call collectives in the same
        order — the usual SPMD contract.

        A single-part task (all ranks on this worker — what the pack policy
        arranges whenever the task fits one node) completes the collective
        locally: no hub round-trip, no parent traffic.  The serialize
        round-trip is kept so the result has identical copy semantics to the
        spanning case (mutating it never aliases the caller's object)."""
        if self.n_parts == 1:
            if self.cancelled.is_set():
                raise CollectiveError("task cancelled")
            self._seq += 1
            return [serialize.loads(serialize.dumps(obj))]
        seq, self._seq = self._seq, self._seq + 1
        self.hub_calls += 1
        values = self._hub.call(self.uid, self.attempt, seq, self.part,
                                serialize.dumps(obj), self._coll_timeout)
        return [serialize.loads(v) for v in values]

    def barrier(self):
        self.allgather(None)

    def bcast(self, obj, root: int = 0):
        """Broadcast ``obj`` from part ``root`` to every part."""
        return self.allgather(obj if self.part == root else None)[root]


class Worker:
    def __init__(self, addr: tuple, worker_id: str, n_devices: int,
                 heartbeat: float, token: str):
        self.worker_id = worker_id
        self.n_devices = n_devices
        self.heartbeat = heartbeat
        self.token = token
        sock = socket.create_connection(addr, timeout=30)
        # the connect timeout must NOT linger on the established channel: an
        # idle worker (no launches for 30s) would hit a recv timeout and die
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.chan = Channel(sock)
        self.hub = _Hub(self.chan)
        self._tasks: dict = {}   # (uid, attempt) -> cancel Event, while the
        # part runs here; doubles as the is-this-attempt-alive check
        self._jax_devices = None

    # --- device inventory -------------------------------------------------
    def _local_devices(self, indices, build_comm: bool):
        if not build_comm:
            return tuple(f"{self.worker_id}:{i}" for i in indices)
        if self._jax_devices is None:
            import jax
            self._jax_devices = jax.devices()
            if len(self._jax_devices) < self.n_devices:
                raise RuntimeError(
                    f"worker {self.worker_id}: XLA exposes "
                    f"{len(self._jax_devices)} devices, parent expected "
                    f"{self.n_devices}")
        return tuple(self._jax_devices[i] for i in indices)

    # --- task parts -------------------------------------------------------
    def _run_part(self, d: dict, cancelled: threading.Event):
        uid, attempt, part = d["uid"], d["attempt"], d["part"]
        comm_s = 0.0
        try:
            devs = self._local_devices(d["local_devices"], d["build_comm"])
            if d["build_comm"]:
                from repro.core.communicator import build_communicator
                shape = d["mesh_shape"] if d["n_parts"] == 1 else None
                local = build_communicator(devs, d["mesh_axes"], shape,
                                           uid=f"task{uid}.p{part}",
                                           placement=d.get("placement", ""))
                comm_s = local.build_seconds
            else:
                local = StubComm(devices=devs,
                                 placement=d.get("placement", ""))
            comm = ProcTaskComm(uid=uid, world_size=d["world_size"],
                                global_ranks=d["global_ranks"], part=part,
                                n_parts=d["n_parts"], local_comm=local,
                                hub=self.hub, attempt=attempt,
                                cancelled=cancelled,
                                placement=d.get("placement", ""))
            fn, args, kwargs = serialize.loads(d["payload"])
            res = fn(comm, *args, **kwargs)
            self.chan.send(protocol.PART_DONE, uid=uid, attempt=attempt,
                           part=part, result=serialize.dumps(res),
                           error=None, comm_build_s=comm_s)
        except ConnectionClosed:
            pass                     # parent is gone; nothing to report to
        except Exception as e:  # noqa: BLE001 — report any payload error
            try:
                self.chan.send(protocol.PART_DONE, uid=uid, attempt=attempt,
                               part=part, result=None,
                               error=f"{type(e).__name__}: {e}",
                               comm_build_s=comm_s)
            except ConnectionClosed:
                pass
        finally:
            self._tasks.pop((uid, attempt), None)
            self.hub.forget(uid, attempt)

    def _log(self, msg: str):
        print(f"[worker {self.worker_id} pid={os.getpid()} "
              f"t={time.time():.3f}] {msg}", file=sys.stderr, flush=True)

    # --- liveness ---------------------------------------------------------
    def _heartbeat_loop(self):
        while True:
            time.sleep(self.heartbeat)
            try:
                self.chan.send(protocol.HEARTBEAT, worker=self.worker_id,
                               t=time.time())
            except ConnectionClosed as e:
                self._log(f"exiting: heartbeat send failed ({e})")
                os._exit(1)          # parent died: no reason to live on

    # --- main loop --------------------------------------------------------
    def run(self):
        self.chan.send(protocol.HELLO, worker=self.worker_id, pid=os.getpid(),
                       n_devices=self.n_devices, token=self.token,
                       platform=sys.platform)
        threading.Thread(target=self._heartbeat_loop, daemon=True).start()
        while True:
            try:
                kind, d = self.chan.recv()
            except ConnectionClosed as e:
                self._log(f"exiting: parent channel closed ({e})")
                os._exit(1)
            if kind == protocol.LAUNCH:
                # register the cancel flag BEFORE the part thread exists so
                # a CANCEL racing the thread start is never lost (frames on
                # one channel are ordered: LAUNCH always precedes CANCEL)
                cancelled = threading.Event()
                self._tasks[(d["uid"], d["attempt"])] = cancelled
                threading.Thread(target=self._run_part, args=(d, cancelled),
                                 daemon=True).start()
            elif kind == protocol.COLL_RESULT:
                self.hub.deliver(d["uid"], d["attempt"], d["seq"],
                                 d["values"])
            elif kind == protocol.COLL_ERROR:
                self.hub.fail(d["uid"], d["attempt"], d.get("seq"),
                              d["error"])
            elif kind == protocol.CANCEL:
                cancelled = self._tasks.get((d["uid"], d["attempt"]))
                if cancelled is not None:    # part still running here
                    cancelled.set()
                    self.hub.fail(d["uid"], d["attempt"], None,
                                  "task cancelled")
            elif kind == protocol.SHUTDOWN:
                self._log("exiting: shutdown requested")
                os._exit(0)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--addr", required=True, help="host:port of the pilot")
    p.add_argument("--worker", required=True)
    p.add_argument("--n-devices", type=int, required=True)
    p.add_argument("--heartbeat", type=float, default=0.5)
    p.add_argument("--token", default="")
    a = p.parse_args(argv)
    host, port = a.addr.rsplit(":", 1)
    Worker((host, int(port)), a.worker, a.n_devices, a.heartbeat,
           a.token).run()


if __name__ == "__main__":
    main()
