"""Worker-process entry point for :class:`ProcessExecutor`.

One worker == one "node" of the paper's pilot: a fresh interpreter whose
XLA_FLAGS were set by the parent (``--xla_force_host_platform_device_count=K``)
so it owns K host devices.  The worker

* dials back to the parent, registers its device inventory (HELLO),
* sends HEARTBEAT frames so the scheduler gets real liveness detection,
* opens a peer-data listener (:class:`_PeerNet`) whose address is advertised
  in the HELLO frame — large collective payloads move worker-to-worker over
  persistent peer channels instead of relaying through the parent hub,
* runs each LAUNCH frame's task *part* in its own thread: builds the local
  sub-mesh communicator, wraps it in a :class:`ProcTaskComm` (which adds
  cross-process collectives via the peer data plane + parent's hub), calls
  the payload, and ships the serialized result back (PART_DONE).

Run as ``python -m repro.core.executors.worker --addr HOST:PORT ...``.
"""
from __future__ import annotations

import argparse
import os
import socket
import sys
import threading
import time
from typing import Optional

from repro.core.executors import protocol, serialize
from repro.core.executors import shm as _shmseg
from repro.core.executors.protocol import Channel, ConnectionClosed
from repro.core.executors.thread import StubComm
from repro.obs import metrics as _metrics
from repro.obs import spans as _spans


class CollectiveError(RuntimeError):
    """A collective could not complete (a participant's worker died)."""


class _Hub:
    """Client side of the parent-coordinated collectives: one outstanding
    request per (uid, attempt, seq), answered by COLL_RESULT or COLL_ERROR.
    ``attempt`` keeps a retried task (same uid) from ever being confused
    with frames or abort markers of its failed predecessor."""

    def __init__(self, chan: Channel):
        self.chan = chan
        self._lock = threading.Lock()
        self._waiting: dict = {}   # (uid, attempt, seq) -> [event, values]
        self._dead: dict = {}      # (uid, attempt) -> error (task aborted)

    def call(self, uid: int, attempt: int, seq: int, part: int,
             payload: bytes, timeout: float) -> list:
        with self._lock:
            if (uid, attempt) in self._dead:
                raise CollectiveError(self._dead[(uid, attempt)])
            slot = [threading.Event(), None]
            self._waiting[(uid, attempt, seq)] = slot
        self.chan.send(protocol.COLL, uid=uid, attempt=attempt, seq=seq,
                       part=part, payload=payload)
        if not slot[0].wait(timeout):
            with self._lock:
                self._waiting.pop((uid, attempt, seq), None)
            raise CollectiveError(
                f"collective uid={uid} seq={seq} timed out after {timeout}s")
        if isinstance(slot[1], Exception):
            raise slot[1]
        return slot[1]

    def deliver(self, uid: int, attempt: int, seq: int, values: list):
        with self._lock:
            slot = self._waiting.pop((uid, attempt, seq), None)
        if slot:
            slot[1] = values
            slot[0].set()

    def fail(self, uid: int, attempt: int, seq: Optional[int], error: str):
        with self._lock:
            self._dead[(uid, attempt)] = error
            keys = [k for k in self._waiting
                    if k[:2] == (uid, attempt) and (seq is None or k[2] == seq)]
            for k in keys:
                slot = self._waiting.pop(k)
                slot[1] = CollectiveError(error)
                slot[0].set()

    def forget(self, uid: int, attempt: int):
        """Drop the abort marker once the attempt's part thread has exited —
        a dead attempt never comes back, and without this the marker dict
        grows by one entry per cancelled attempt for the worker's life."""
        with self._lock:
            self._dead.pop((uid, attempt), None)

    def dead_error(self, uid: int, attempt: int) -> Optional[str]:
        """The abort reason for (uid, attempt), or None while it is live —
        polled by peer-data waits so a COLL_ERROR unblocks them too."""
        with self._lock:
            return self._dead.get((uid, attempt))


class _PeerNet:
    """Worker-to-worker data plane: one listening data port per worker plus
    a cache of persistent outgoing channels, moving collective payloads
    directly between peers (the length-prefixed ``protocol.py`` framing, the
    parent hub never sees the bytes).

    * inbound: every accepted connection authenticates with PEER_HELLO
      (shared pilot token), then streams PEER_DATA frames into the mailbox,
      keyed ``(uid, attempt, seq, src_part)`` — stale frames of a failed
      attempt can never be matched by its retry (different attempt id).
    * outbound: ``send`` reuses one cached channel per destination worker;
      a send failure drops the cached channel and retries once on a fresh
      connection, then reports failure so the caller can fall back to the
      hub relay — a dead peer never wedges a collective.
    """

    #: purged-attempt tombstones kept (FIFO); bounds the memory a late frame
    #: race can cost while covering far more history than can be in flight
    MAX_TOMBSTONES = 4096

    def __init__(self, worker_id: str, token: str):
        self.worker_id = worker_id
        self.token = token
        self.data_addr: Optional[tuple] = None    # (host, port) advertised
        self._cv = threading.Condition()
        self._mail: dict = {}                     # key -> payload bytes
        self._done: dict = {}                     # (uid, attempt) tombstones
        # of purged attempts (insertion-ordered): peer and hub channels have
        # no mutual ordering, so a frame may arrive AFTER its attempt ended
        # — without the tombstone it would park in the mailbox forever
        self._out: dict = {}                      # dest worker id -> Channel
        self._out_lock = threading.Lock()
        self._server: Optional[socket.socket] = None
        # shared-memory ledger: segments THIS worker created per attempt,
        # reclaimed by purge(failed=True) when the attempt aborts before
        # receivers could consume them (the receiver unlinks on consume)
        self._shm_sent: dict = {}                 # (uid, attempt) -> [name]
        self._shm_lock = threading.Lock()

    # --- inbound ----------------------------------------------------------
    def start(self, advertise_host: str):
        """Open the data port (any interface — multi-host workers need only
        a routable address book) and advertise ``advertise_host``: the local
        address of the parent channel, i.e. the interface peers on other
        hosts can reach the same way the parent does."""
        srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        srv.bind(("", 0))
        srv.listen(64)
        self._server = srv
        self.data_addr = (advertise_host, srv.getsockname()[1])
        threading.Thread(target=self._accept_loop, daemon=True).start()

    def _accept_loop(self):
        while True:
            try:
                sock, _ = self._server.accept()
            except OSError:
                return
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            sock.settimeout(10.0)     # bound the PEER_HELLO handshake
            threading.Thread(target=self._serve, args=(Channel(sock),),
                             daemon=True).start()

    def _serve(self, chan: Channel):
        try:
            kind, d = chan.recv()
            if kind != protocol.PEER_HELLO or d.get("token") != self.token:
                chan.close()
                return
            chan.sock.settimeout(None)
            while True:
                kind, d = chan.recv()
                if kind == protocol.PEER_DATA:
                    self.put((d["uid"], d["attempt"], d["seq"], d["part"]),
                             d["payload"])
                elif kind in (protocol.PEER_DATA_RAW, protocol.PEER_DATA_GEN,
                              protocol.PEER_DATA_SHM):
                    # raw / generic / shm frame: park the whole header dict
                    # — it carries the layout metadata next to the raw body
                    # the Channel already read off the stream (or the name
                    # of the shared-memory segment holding it)
                    if kind == protocol.PEER_DATA_SHM:
                        # eager consume: copy the segment body out HERE so
                        # the tmpfs read overlaps the collective's hub
                        # barrier (matching the pipelining a streamed TCP
                        # body gets for free) and the segment's lifetime
                        # ends the moment the header lands.  A vanished
                        # segment (sender aborted and purged) keeps its
                        # "shm" key: the claimer surfaces the error.
                        try:
                            d["payload"] = _shmseg.read(d["shm"])
                            _shmseg.unlink(d.pop("shm"))
                        except OSError:
                            pass
                    self.put((d["uid"], d["attempt"], d["seq"], d["part"]), d)
        except (ConnectionClosed, OSError):
            chan.close()

    # --- mailbox ----------------------------------------------------------
    def put(self, key: tuple, payload):
        dropped = None
        with self._cv:
            if key[:2] in self._done:
                dropped = payload     # attempt already ended: unclaimable
            else:
                dropped = self._mail.get(key)    # displaced duplicate (a
                # ring rescue and a recovered link can both deliver a block)
                self._mail[key] = payload
                self._cv.notify_all()
        _discard_frame(dropped)

    def take(self, key: tuple, timeout: float, abort=None) -> bytes:
        """Blocking receive of one peer payload.  ``abort()`` (if given)
        returns an error string once the task is being torn down — a worker
        dying mid-transfer surfaces as the parent's COLL_ERROR/CANCEL, which
        must unblock this wait promptly instead of running out the clock."""
        deadline = time.monotonic() + timeout
        with self._cv:
            while True:
                if key in self._mail:
                    return self._mail.pop(key)
                if abort is not None:
                    err = abort()
                    if err:
                        raise CollectiveError(err)
                left = deadline - time.monotonic()
                if left <= 0:
                    raise CollectiveError(
                        f"peer payload {key} not received within {timeout}s")
                self._cv.wait(min(left, 0.05))

    def purge(self, uid: int, attempt: int, failed: bool = False):
        """Drop parked payloads of a finished/aborted attempt — they can
        never be claimed (keys carry the attempt id) and would otherwise
        accumulate for the worker's life.  The attempt is tombstoned so a
        frame still in flight on a peer channel is dropped on arrival.

        Parked shared-memory frames are unlinked here (nobody will consume
        them), and ``failed=True`` additionally reclaims every segment THIS
        worker created for the attempt: an aborted attempt's receivers
        raise out of their takes without consuming.  A clean finish leaves
        sent segments to the receivers, who unlink on consume."""
        with self._cv:
            dropped = []
            for k in [k for k in self._mail
                      if k[0] == uid and k[1] == attempt]:
                dropped.append(self._mail.pop(k))
            self._done[(uid, attempt)] = None
            while len(self._done) > self.MAX_TOMBSTONES:
                del self._done[next(iter(self._done))]
        for f in dropped:
            _discard_frame(f)
        with self._shm_lock:
            names = self._shm_sent.pop((uid, attempt), ())
        if failed:
            for name in names:
                _shmseg.unlink(name)

    def record_segment(self, uid: int, attempt: int, name: str):
        """Ledger a shared-memory segment created for (uid, attempt) so an
        aborted attempt's purge can reclaim it (see :meth:`purge`)."""
        with self._shm_lock:
            self._shm_sent.setdefault((uid, attempt), []).append(name)

    # --- outbound ---------------------------------------------------------
    def _channel(self, wid: str, addr: tuple,
                 fresh: bool = False) -> Optional[Channel]:
        if not fresh:
            with self._out_lock:
                chan = self._out.get(wid)
            if chan is not None:
                return chan
        try:
            sock = socket.create_connection(addr, timeout=5.0)
        except OSError:
            return None
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        chan = Channel(sock)
        try:
            chan.send(protocol.PEER_HELLO, worker=self.worker_id,
                      token=self.token)
        except ConnectionClosed:
            chan.close()
            return None
        with self._out_lock:
            old = self._out.get(wid)
            self._out[wid] = chan
        if old is not None and old is not chan:
            old.close()
        return chan

    def evict(self, wid: str):
        """Close and drop the cached outgoing channel to ``wid`` — called
        when the parent announces the peer retired or died (PEERS_UPDATE).
        Without this the half-dead channel lingers for the worker's life;
        worse, if a task's address book ever re-used the id, the first send
        would burn its one retry on the stale socket."""
        with self._out_lock:
            chan = self._out.pop(wid, None)
        if chan is not None:
            chan.close()

    def send_kind(self, wid: str, addr: tuple, kind: str, bufs=None,
                  **fields) -> bool:
        """Ship one peer frame of ``kind`` to worker ``wid``; True on
        success.  ``bufs`` (for RAW_BODY_KINDS) are written to the stream
        as the raw body after the header.  A stale cached channel (peer
        restarted its end, half-closed socket) is dropped and retried ONCE
        on a fresh connection — never reused for the caller's retry
        attempt."""
        for fresh in (False, True):
            chan = self._channel(wid, addr, fresh=fresh)
            if chan is None:
                continue
            try:
                if bufs is not None:
                    chan.send_raw(kind, bufs, **fields)
                else:
                    chan.send(kind, **fields)
                return True
            except ConnectionClosed:
                with self._out_lock:
                    if self._out.get(wid) is chan:
                        del self._out[wid]
                chan.close()
        return False

    def send(self, wid: str, addr: tuple, **fields) -> bool:
        """Ship one pickled-body PEER_DATA frame (see :meth:`send_kind`)."""
        return self.send_kind(wid, addr, protocol.PEER_DATA, **fields)

    def send_raw(self, wid: str, addr: tuple, bufs, **fields) -> bool:
        """Ship one PEER_DATA_RAW frame — header + raw buffer bytes, no
        pickle of the body (see :meth:`send_kind`)."""
        return self.send_kind(wid, addr, protocol.PEER_DATA_RAW, bufs=bufs,
                              **fields)


def _discard_frame(frame):
    """Reclaim resources owned by a peer frame that will never be consumed
    (tombstoned attempt, displaced duplicate): a shared-memory frame's
    segment must be unlinked NOW — the consume path will never see it."""
    if isinstance(frame, dict) and frame.get("shm"):
        _shmseg.unlink(frame["shm"])


def _encode_cols(chunk: dict):
    """Wire form of a column-dict for a raw peer frame: ``(metas, bufs)``
    where ``metas`` is ``[(name, dtype_str, shape), ...]`` (pickled in the
    frame header) and ``bufs`` the matching C-contiguous arrays whose bytes
    follow the header verbatim.  Column order is sorted-by-name so both
    sides agree without shipping an ordering."""
    import numpy as np
    metas, bufs = [], []
    for name in sorted(chunk):
        a = np.ascontiguousarray(chunk[name])
        metas.append((name, a.dtype.str, a.shape))
        bufs.append(a)
    return metas, bufs


def _decode_cols(metas, payload: bytes) -> dict:
    """Inverse of :func:`_encode_cols`: zero-copy ``np.frombuffer`` views
    into ``payload``.  The views are read-only (they alias the received
    bytes) — callers that mutate must copy first."""
    import numpy as np
    out, off = {}, 0
    for name, dtype, shape in metas:
        dt = np.dtype(dtype)
        count = 1
        for s in shape:
            count *= int(s)
        out[name] = np.frombuffer(payload, dt, count=count,
                                  offset=off).reshape(shape)
        off += dt.itemsize * count
    return out


class _WirePayload:
    """One collective payload in wire-ready form: either pickled (``data``
    set) or raw-split (``skel``/``metas``/``bufs`` set — the
    ``serialize.dumps_arrays`` shape, where ``bufs`` holds the array leaves
    on the sending side or the single received body-bytes object on a ring
    forward)."""

    __slots__ = ("data", "skel", "metas", "bufs")

    def __init__(self, data=None, skel=None, metas=None, bufs=None):
        self.data = data
        self.skel = skel
        self.metas = metas
        self.bufs = bufs

    @property
    def nbytes(self) -> int:
        """Raw body size: what a peer frame's stream body (or shm segment)
        carries."""
        if self.data is not None:
            return len(self.data)
        return sum(memoryview(b).nbytes for b in self.bufs)

    @property
    def size(self) -> int:
        """Total wire size, for threshold decisions (raw adds the pickled
        skeleton that rides in the frame header)."""
        if self.data is not None:
            return len(self.data)
        return len(self.skel) + self.nbytes


class ProcTaskComm:
    """The communicator a payload receives under :class:`ProcessExecutor`.

    Mirrors the thread-mode ``Communicator`` surface (``mesh``, ``devices``,
    ``build_seconds``) for the ranks local to THIS worker, and adds the
    cross-process view: ``size`` is the task's total rank count (the paper's
    heterogeneous communicator spanning nodes), ``local_size`` the ranks this
    process owns, and ``allgather``/``bcast``/``barrier`` coordinate all
    parts through the pilot's hub.  Payloads written for ``ThreadExecutor``
    keep working unchanged as long as the task fits one worker (then
    ``size == local_size`` and ``mesh`` covers every rank).

    Data plane: when the LAUNCH frame carried a complete peer address book
    (``peer_addrs``), a collective payload larger than ``p2p_threshold``
    moves DIRECTLY to every peer worker over persistent peer channels; the
    hub round-trip still happens per collective, but carries only the tiny
    ``PEER_SENT`` placeholder — it is the ordering/barrier control frame,
    not a data relay.  Payloads at or under the threshold (barrier tokens,
    small scalars) stay inline on the hub frame.  If any peer send fails,
    THIS part's payload falls back to the hub frame for that collective
    (``p2p_fallbacks``) and every receiver still completes — receivers
    decide per hub value whether to read it inline or await the peer copy,
    so mixed outcomes cannot deadlock.

    Transport tiers (chosen per payload, per destination, best first):

    1. **same-host shared memory** — the address book says the peer is on
       this host: the body goes into a ``multiprocessing.shared_memory``
       segment, only name + layout header on the socket (``shm_bytes``).
    2. **raw peer frame** — array leaves ship as raw bytes after a pickled
       skeleton header, no pickle pass over the body (``raw_coll_bytes``;
       PEER_DATA_GEN, the generic sibling of the shuffle's PEER_DATA_RAW).
    3. **pickled peer frame** — cloudpickle body on the peer channel
       (payloads with no array leaves, or ``raw_frames=False``).
    4. **hub relay** — the per-payload fallback when no peer tier works.

    Wide tasks (``n_parts >= RING_MIN_PARTS``) additionally replace the
    every-part-sends-to-every-peer allgather with a P-1 step ring
    (``ring_steps``), cutting per-link traffic from O(P·B) to O(B); parts
    2-3 keep the direct path (fewer hops, same bytes).  Remote entries of
    a raw-framed gather are read-only ``np.frombuffer`` views — copy
    before mutating in place (the shuffle-frame contract)."""

    #: ring allgather needs at least this many parts to beat direct sends
    RING_MIN_PARTS = 4

    def __init__(self, uid: int, world_size: int, global_ranks: tuple,
                 part: int, n_parts: int, local_comm, hub: _Hub,
                 attempt: int = 0, coll_timeout: float = 120.0,
                 cancelled: Optional[threading.Event] = None,
                 placement: str = "", peer_net: Optional[_PeerNet] = None,
                 peer_addrs: Optional[list] = None,
                 p2p_threshold: int = 1024, raw_frames: bool = True,
                 ring: bool = True, shm: bool = True,
                 registry=None):
        self.uid = uid
        self.attempt = attempt
        self.world_size = world_size
        self.global_ranks = tuple(global_ranks)
        self.part = part
        self.n_parts = n_parts
        self.local_comm = local_comm
        self.cancelled = cancelled or threading.Event()
        self.placement = placement   # policy that placed this task (pack|
        # spread); under pack a fitting task has n_parts == 1 and its
        # collectives below never touch the hub
        # comm counters live in a part-local MetricsRegistry (chained to the
        # worker-lifetime registry whose snapshot rides every heartbeat)
        # rather than ad-hoc attributes; the attribute surface below —
        # ``comm.spills += n`` — is preserved by properties whose setter
        # feeds the delta through the registry, so payloads and the parent's
        # telemetry always agree without double bookkeeping
        self.metrics = registry if registry is not None \
            else _metrics.MetricsRegistry()
        self.checkpoint = None        # CheckpointContext bound by the worker
        # when the LAUNCH carried a checkpoint namespace (REPRO_CKPT_DIR)
        self.raw_frames = raw_frames  # raw-body peer frames enabled (knob
        # for A/B benchmarking against the pickled PEER_DATA path)
        self.ring = ring              # ring allgather for wide tasks
        self.shm = shm and _shmseg.HAVE_SHM   # same-host segment handoff
        self._hub = hub
        self._seq = 0
        self._coll_timeout = coll_timeout
        self._peer_net = peer_net
        self._peer_addrs = list(peer_addrs or [])
        self.p2p_threshold = p2p_threshold
        # the data plane is usable only when EVERY part advertised a data
        # port: a sender must know all destinations, and a sentinel in the
        # hub values obliges every receiver to await a peer frame
        self._peers_ok = (peer_net is not None
                          and len(self._peer_addrs) == n_parts
                          and all(a is not None for a in self._peer_addrs))
        # this part's advertised host: the same-host test for the shm tier
        # compares address-book entries, never re-resolves interfaces
        self._host = self._peer_addrs[part][1] if self._peers_ok else None

    # --- registry-backed comm counters (attribute surface preserved) -----
    @property
    def hub_calls(self) -> int:
        """Parent-hub round-trips actually paid."""
        return self.metrics.get("hub_calls")

    @hub_calls.setter
    def hub_calls(self, v: int):
        self.metrics.set_counter("hub_calls", v)

    @property
    def p2p_bytes(self) -> int:
        """Payload bytes this part SENT over peer channels (each transferred
        byte is counted exactly once, by its sender; sim/thread comms expose
        the same field as a constant 0)."""
        return self.metrics.get("p2p_bytes")

    @p2p_bytes.setter
    def p2p_bytes(self, v: int):
        self.metrics.set_counter("p2p_bytes", v)

    @property
    def p2p_fallbacks(self) -> int:
        """Above-threshold payloads that had to relay through the hub
        because a peer channel could not be used."""
        return self.metrics.get("p2p_fallbacks")

    @p2p_fallbacks.setter
    def p2p_fallbacks(self, v: int):
        self.metrics.set_counter("p2p_fallbacks", v)

    @property
    def spills(self) -> int:
        """Shuffle partitions a payload spilled to disk on this part
        (incremented by the payload via SpillBuffer; sim/thread comms expose
        the same field as a constant 0)."""
        return self.metrics.get("spills")

    @spills.setter
    def spills(self, v: int):
        self.metrics.set_counter("spills", v)

    @property
    def raw_coll_bytes(self) -> int:
        """Collective payload bytes this part sent with zero-copy raw
        framing (generic PEER_DATA_GEN frames plus raw-layout shm segments)
        — the bytes that never passed through pickle."""
        return self.metrics.get("raw_coll_bytes")

    @raw_coll_bytes.setter
    def raw_coll_bytes(self, v: int):
        self.metrics.set_counter("raw_coll_bytes", v)

    @property
    def shm_bytes(self) -> int:
        """Payload bytes this part handed to same-host peers through
        shared-memory segments (counted by the sender, like p2p_bytes)."""
        return self.metrics.get("shm_bytes")

    @shm_bytes.setter
    def shm_bytes(self, v: int):
        self.metrics.set_counter("shm_bytes", v)

    @property
    def ring_steps(self) -> int:
        """Ring-allgather forwards this part performed (each moves ONE
        part's block one hop; a wide gather costs P-1 per part)."""
        return self.metrics.get("ring_steps")

    @ring_steps.setter
    def ring_steps(self, v: int):
        self.metrics.set_counter("ring_steps", v)

    # --- Communicator-compatible surface (local ranks) -------------------
    @property
    def mesh(self):
        return self.local_comm.mesh

    @property
    def devices(self) -> tuple:
        return tuple(self.local_comm.devices)

    @property
    def build_seconds(self) -> float:
        return self.local_comm.build_seconds

    @property
    def size(self) -> int:
        """Total ranks of the task across all workers."""
        return self.world_size

    @property
    def local_size(self) -> int:
        return len(self.global_ranks)

    @property
    def rank(self) -> int:
        """First global rank owned by this part."""
        return self.global_ranks[0]

    def sub(self, axis: str):
        return self.local_comm.sub(axis)

    # --- transport tiers: encode / ship / receive / decode ----------------
    def _encode(self, obj) -> _WirePayload:
        """Wire form of one collective payload: raw-split when raw framing
        is on and the payload has array leaves, else pickled."""
        if self.raw_frames:
            split = serialize.dumps_arrays(obj)
            if split is not None:
                skel, metas, bufs = split
                return _WirePayload(skel=skel, metas=metas, bufs=bufs)
        return _WirePayload(data=serialize.dumps(obj))

    def _hub_form(self, pl: _WirePayload, obj) -> bytes:
        """The payload as inline hub bytes (small payloads and per-payload
        fallback) — always plain pickle, whatever tier was attempted."""
        return pl.data if pl.data is not None else serialize.dumps(obj)

    def _ship(self, dest: int, pl: _WirePayload, seq: int,
              origin: Optional[int] = None) -> bool:
        """Ship one wire payload to part ``dest`` down the tier ladder:
        same-host shared memory -> raw peer frame -> pickled peer frame.
        ``origin`` keys the frame when forwarding another part's ring
        block.  False when no peer tier could deliver — the caller falls
        back to the hub (own payload) or to direct sends around the dead
        link (forwarded block)."""
        wid, host, port = self._peer_addrs[dest]
        head = dict(uid=self.uid, attempt=self.attempt, seq=seq,
                    part=self.part if origin is None else origin)
        raw = pl.data is None
        nbytes = pl.nbytes
        if (self.shm and self._host is not None and host == self._host
                and nbytes > self.p2p_threshold):
            name = _shmseg.segment_name(self._peer_net.token,
                                        self._peer_net.worker_id)
            ok = True
            try:
                _shmseg.write(name, pl.bufs if raw else [pl.data])
            except OSError:
                ok = False           # /dev/shm full/unusable: next tier
                _shmseg.unlink(name)
            if ok:
                if self._peer_net.send_kind(
                        wid, (host, port), protocol.PEER_DATA_SHM,
                        shm=name, nbytes=nbytes, skel=pl.skel,
                        arrs=pl.metas, **head):
                    self._peer_net.record_segment(self.uid, self.attempt,
                                                  name)
                    self.p2p_bytes += nbytes
                    self.shm_bytes += nbytes
                    if raw:
                        self.raw_coll_bytes += nbytes
                    return True
                _shmseg.unlink(name)   # header never left: reclaim now
        if raw:
            if self._peer_net.send_kind(wid, (host, port),
                                        protocol.PEER_DATA_GEN,
                                        bufs=pl.bufs, skel=pl.skel,
                                        arrs=pl.metas, **head):
                self.p2p_bytes += nbytes
                self.raw_coll_bytes += nbytes
                return True
            return False
        if self._peer_net.send_kind(wid, (host, port), protocol.PEER_DATA,
                                    payload=pl.data, **head):
            self.p2p_bytes += nbytes
            return True
        return False

    def _abort_reason(self) -> Optional[str]:
        return ("task cancelled" if self.cancelled.is_set()
                else self._hub.dead_error(self.uid, self.attempt))

    def _take_frame(self, seq: int, origin: int):
        with _spans.current_recorder().span("p2p_recv"):
            return self._peer_net.take(
                (self.uid, self.attempt, seq, origin), self._coll_timeout,
                abort=self._abort_reason)

    def _frame_payload(self, frame) -> _WirePayload:
        """One received peer frame back in wire-ready form, whichever tier
        carried it — ring forwarding needs the body bytes in hand, and a
        shm segment must be consumed (copied out + unlinked) exactly
        once."""
        if not isinstance(frame, dict):      # PEER_DATA: pickled bytes
            return _WirePayload(data=frame)
        if frame.get("shm"):
            body = self._consume_segment(frame)
        else:
            body = frame["payload"]
        if frame.get("skel") is not None:
            return _WirePayload(skel=frame["skel"], metas=frame["arrs"],
                                bufs=[body])
        return _WirePayload(data=body)

    def _consume_segment(self, frame) -> bytes:
        """Copy a shm frame's body out of its segment and unlink it —
        whoever received the header owns the cleanup."""
        try:
            return _shmseg.read(frame["shm"])
        except (FileNotFoundError, OSError) as e:
            # the sender aborted and reclaimed it; this attempt is dying
            raise CollectiveError(
                f"shm segment {frame['shm']} vanished before consume "
                f"({e})") from e
        finally:
            _shmseg.unlink(frame["shm"])

    def _decode(self, pl: _WirePayload):
        """A received wire payload back as the object (raw array leaves are
        zero-copy read-only views into the received body)."""
        if pl.data is not None:
            return serialize.loads(pl.data)
        body = (pl.bufs[0] if len(pl.bufs) == 1
                else b"".join(memoryview(b).cast("B") for b in pl.bufs))
        return serialize.loads_arrays(pl.skel, pl.metas, body)

    def _decode_own(self, pl: _WirePayload):
        """This part's own entry of a gathered result, with the same
        no-aliasing guarantee as remote entries: raw leaves are rebuilt as
        views into a fresh copy of the body, never the caller's arrays."""
        if pl.data is not None:
            return serialize.loads(pl.data)
        body = b"".join(memoryview(b).cast("B") for b in pl.bufs)
        return serialize.loads_arrays(pl.skel, pl.metas, body)

    # --- cross-process collectives (per-part granularity) -----------------
    def allgather(self, obj) -> list:
        """Gather one object per *part* (worker share), same list everywhere,
        ordered by part index.  Parts must call collectives in the same
        order — the usual SPMD contract.

        A single-part task (all ranks on this worker — what the pack policy
        arranges whenever the task fits one node) completes the collective
        locally: no hub round-trip, no parent traffic; array leaves are
        copied directly instead of round-tripping through pickle, with the
        same never-aliases-the-input guarantee.

        A spanning task ships large payloads worker-to-worker down the tier
        ladder (see the class docstring), direct to every peer for 2-3
        parts and around the ring for wide tasks; the hub round-trip
        remains as the per-collective control barrier and the automatic
        fallback carrier."""
        if self.n_parts == 1:
            if self.cancelled.is_set():
                raise CollectiveError("task cancelled")
            self._seq += 1
            return [serialize.copy_local(obj)]
        pl = self._encode(obj)
        if (self.ring and self._peers_ok
                and self.n_parts >= self.RING_MIN_PARTS):
            return self._allgather_ring(obj, pl)
        return self._allgather_direct(obj, pl)

    def _allgather_direct(self, obj, pl: _WirePayload) -> list:
        seq, self._seq = self._seq, self._seq + 1
        rec = _spans.current_recorder()
        hub_payload = None
        if self._peers_ok and pl.size > self.p2p_threshold:
            with rec.span("p2p_send"):
                sent = True
                for p in range(self.n_parts):
                    if p != self.part and not self._ship(p, pl, seq):
                        sent = False
                        break
            if sent:
                hub_payload = protocol.PEER_SENT
            else:
                # a peer copy may already be parked at some receivers; they
                # will prefer the hub value and purge the duplicate at task
                # end — correctness never depends on which copy is used
                self.p2p_fallbacks += 1
        if hub_payload is None:
            hub_payload = self._hub_form(pl, obj)
        self.hub_calls += 1
        with rec.span("p2p_recv"):
            values = self._hub.call(self.uid, self.attempt, seq, self.part,
                                    hub_payload, self._coll_timeout)
        out = []
        for j, v in enumerate(values):
            if v != protocol.PEER_SENT:
                out.append(serialize.loads(v))
            elif j == self.part:
                out.append(self._decode_own(pl))
            else:
                out.append(self._decode(self._frame_payload(
                    self._take_frame(seq, j))))
        return out

    def _allgather_ring(self, obj, pl: _WirePayload) -> list:
        """Wide allgather as a P-1 step ring: every part forwards exactly
        one block per step to its next neighbor, so each link carries O(B)
        per step instead of each part pushing O(P·B) direct copies.  The
        hub round runs FIRST as the control barrier: small payloads ride
        it inline, large ones announce PEER_SENT — so the set of ring
        blocks is agreed by every part before any block moves.  A failed
        forward degrades THAT BLOCK to direct sends for the parts
        downstream (one bad link never tears down the collective); a
        genuinely dead peer aborts the attempt through the parent's
        COLL_ERROR exactly as on the direct path."""
        seq, self._seq = self._seq, self._seq + 1
        rec = _spans.current_recorder()
        n, i = self.n_parts, self.part
        if pl.size > self.p2p_threshold:
            hub_payload = protocol.PEER_SENT
        else:
            hub_payload = self._hub_form(pl, obj)
        self.hub_calls += 1
        with rec.span("p2p_recv"):
            values = self._hub.call(self.uid, self.attempt, seq, self.part,
                                    hub_payload, self._coll_timeout)
        ring = {j for j, v in enumerate(values) if v == protocol.PEER_SENT}
        blocks = {i: pl}
        nxt = (i + 1) % n
        for step in range(n - 1):
            o_send = (i - step) % n
            o_recv = (i - 1 - step) % n
            if o_send in ring:
                with rec.span("p2p_send"):
                    if self._ship(nxt, blocks[o_send], seq, origin=o_send):
                        self.ring_steps += 1
                    else:
                        self._ring_rescue(o_send, blocks[o_send], seq)
            if o_recv in ring:
                blocks[o_recv] = self._frame_payload(
                    self._take_frame(seq, o_recv))
        out = []
        for j in range(n):
            if j == i:
                out.append(self._decode_own(pl))
            elif values[j] != protocol.PEER_SENT:
                out.append(serialize.loads(values[j]))
            else:
                out.append(self._decode(blocks[j]))
        return out

    def _ring_rescue(self, origin: int, pl: _WirePayload, seq: int):
        """The forward link is down: direct-ship ``origin``'s block to
        every part downstream of here that has not seen it yet (best
        effort — a part that gets nothing times out into the attempt-level
        retry).  Duplicates a recovered neighbor may also deliver are
        harmless: the mailbox keeps one copy per key and task-end purge
        reclaims strays."""
        self.p2p_fallbacks += 1
        p = (self.part + 1) % self.n_parts
        while p != origin:
            self._ship(p, pl, seq, origin=origin)
            p = (p + 1) % self.n_parts

    def all_to_all_arrays(self, chunks: list) -> list:
        """Personalized all-to-all of numpy column chunks — the shuffle
        bucket exchange.  ``chunks[j]`` (a dict name -> contiguous ndarray)
        is destined for part ``j``; returns ``n_parts`` dicts where entry
        ``i`` is what part ``i`` sent HERE.

        Transport: each destination's chunk ships as ONE ``PEER_DATA_RAW``
        frame — pickled dtype/shape header followed by the columns' raw
        bytes, no pickle round-trip for the body (the dominant cost of the
        pickled path at MB scale).  The control :meth:`allgather` below is
        the per-exchange barrier; a destination whose raw send failed (peer
        unreachable, raw framing disabled, peer plane down) falls back PER
        PAYLOAD to riding that control frame as a plain pickled chunk, so
        mixed outcomes cannot deadlock.  Received raw columns are read-only
        ``np.frombuffer`` views — copy before mutating in place."""
        import numpy as np
        if len(chunks) != self.n_parts:
            raise ValueError(f"all_to_all_arrays: {len(chunks)} chunks for "
                             f"{self.n_parts} parts")
        raw = "__raw__"              # control marker: "await the peer frame"
        use_raw = self._peers_ok and self.raw_frames
        # claim a private seq for the raw frames: both the sender's frame key
        # and the receiver's take() derive it from the SAME lockstep counter
        # the control allgather advances, so no extra coordination is needed
        raw_seq, control = self._seq, [None] * self.n_parts
        rec = _spans.current_recorder()
        for j in range(self.n_parts):
            if j == self.part:
                continue
            sent = False
            if use_raw:
                metas, bufs = _encode_cols(chunks[j])
                wid, host, port = self._peer_addrs[j]
                with rec.span("p2p_send"):
                    sent = self._peer_net.send_raw(
                        wid, (host, port), bufs, uid=self.uid,
                        attempt=self.attempt, seq=raw_seq, part=self.part,
                        cols=metas)
                if sent:
                    self.p2p_bytes += sum(b.nbytes for b in bufs)
            if sent:
                control[j] = raw
            else:
                if use_raw:
                    self.p2p_fallbacks += 1
                control[j] = chunks[j]   # pickled fallback on the barrier
        self._seq += 1                   # consume raw_seq on every part,
        # sends or not — the counters must stay lockstep across parts
        gathered = self.allgather(control)
        out = []
        for i in range(self.n_parts):
            if i == self.part:
                # same copy semantics as allgather's local short-circuit:
                # the returned chunk never aliases the caller's arrays
                out.append({k: np.array(v) for k, v in chunks[i].items()})
                continue
            ctrl = gathered[i][self.part]
            if isinstance(ctrl, str) and ctrl == raw:
                with rec.span("p2p_recv"):
                    d = self._peer_net.take(
                        (self.uid, self.attempt, raw_seq, i),
                        self._coll_timeout,
                        abort=lambda: ("task cancelled"
                                       if self.cancelled.is_set()
                                       else self._hub.dead_error(
                                           self.uid, self.attempt)))
                out.append(_decode_cols(d["cols"], d["payload"]))
            else:
                out.append(ctrl)
        return out

    def barrier(self):
        self.allgather(None)

    def bcast(self, obj, root: int = 0):
        """Broadcast ``obj`` from part ``root`` to every part: the root
        fans its payload out down the tier ladder while non-root parts
        contribute ZERO-BYTE tokens to the barrier frame — nobody pickles
        or ships placeholder values, and each receiver decodes only the
        root's entry instead of all P."""
        if self.n_parts == 1:
            if self.cancelled.is_set():
                raise CollectiveError("task cancelled")
            self._seq += 1
            return serialize.copy_local(obj)
        seq, self._seq = self._seq, self._seq + 1
        rec = _spans.current_recorder()
        pl = None
        if self.part == root:
            pl = self._encode(obj)
            hub_payload = None
            if self._peers_ok and pl.size > self.p2p_threshold:
                with rec.span("p2p_send"):
                    sent = True
                    for p in range(self.n_parts):
                        if p != root and not self._ship(p, pl, seq):
                            sent = False
                            break
                if sent:
                    hub_payload = protocol.PEER_SENT
                else:
                    self.p2p_fallbacks += 1
            if hub_payload is None:
                hub_payload = self._hub_form(pl, obj)
        else:
            hub_payload = b""        # control-only barrier contribution
        self.hub_calls += 1
        with rec.span("p2p_recv"):
            values = self._hub.call(self.uid, self.attempt, seq, self.part,
                                    hub_payload, self._coll_timeout)
        if self.part == root:
            return self._decode_own(pl)
        v = values[root]
        if v == protocol.PEER_SENT:
            return self._decode(self._frame_payload(
                self._take_frame(seq, root)))
        return serialize.loads(v)


class Worker:
    def __init__(self, addr: tuple, worker_id: str, n_devices: int,
                 heartbeat: float, token: str, p2p: bool = True):
        self.worker_id = worker_id
        self.n_devices = n_devices
        self.heartbeat = heartbeat
        self.token = token
        sock = socket.create_connection(addr, timeout=30)
        # the connect timeout must NOT linger on the established channel: an
        # idle worker (no launches for 30s) would hit a recv timeout and die
        sock.settimeout(None)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        self.chan = Channel(sock)
        self.hub = _Hub(self.chan)
        self.peer_net: Optional[_PeerNet] = None
        if p2p:
            self.peer_net = _PeerNet(worker_id, token)
            # advertise the interface the parent is reached through — the
            # one address peers on other hosts can route to as well
            self.peer_net.start(sock.getsockname()[0])
        self._tasks: dict = {}   # (uid, attempt) -> cancel Event, while the
        # part runs here; doubles as the is-this-attempt-alive check
        self._jax_devices = None
        # worker-lifetime flight-recorder registry: every part's comm
        # registry chains into it (counters: hub_calls, p2p_bytes,
        # p2p_fallbacks, spills, spill_bytes) and its snapshot rides every
        # HEARTBEAT frame as the telemetry the parent surfaces as trace
        # events — liveness and observability share one frame
        self.metrics = _metrics.MetricsRegistry()
        self.metrics.gauge("queue_depth", lambda: len(self._tasks))
        self.metrics.gauge("rss_mb", _metrics.rss_mb)
        if self.peer_net is not None:
            self.metrics.gauge("peer_channels",
                               lambda: len(self.peer_net._out))

    # --- device inventory -------------------------------------------------
    def _local_devices(self, indices, build_comm: bool):
        if not build_comm:
            return tuple(f"{self.worker_id}:{i}" for i in indices)
        if self._jax_devices is None:
            import jax
            self._jax_devices = jax.devices()
            if len(self._jax_devices) < self.n_devices:
                raise RuntimeError(
                    f"worker {self.worker_id}: XLA exposes "
                    f"{len(self._jax_devices)} devices, parent expected "
                    f"{self.n_devices}")
        return tuple(self._jax_devices[i] for i in indices)

    # --- task parts -------------------------------------------------------
    def _run_part(self, d: dict, cancelled: threading.Event):
        uid, attempt, part = d["uid"], d["attempt"], d["part"]
        comm_s = 0.0
        comm = None
        rec = _spans.SpanRecorder()
        t_recv = d.pop("_recv_t", None)
        if t_recv is not None:
            rec.add("launch_recv", t_recv, time.perf_counter())
        ckpt = None
        if d.get("ckpt_dir"):
            # per-(lineage, attempt, part) checkpoint handle — a retried or
            # speculated attempt restores the previous attempt's durable
            # steps from the shared part scope (see train.checkpoint)
            from repro.train.checkpoint import CheckpointContext
            ckpt = CheckpointContext(d["ckpt_dir"],
                                     attempt=d.get("ckpt_attempt") or "a0",
                                     part=part, n_parts=d["n_parts"])

        def stats() -> dict:
            return {"p2p_bytes": comm.p2p_bytes if comm else 0,
                    "hub_calls": comm.hub_calls if comm else 0,
                    "p2p_fallbacks": comm.p2p_fallbacks if comm else 0,
                    "spills": comm.spills if comm else 0,
                    "raw_coll_bytes": comm.raw_coll_bytes if comm else 0,
                    "shm_bytes": comm.shm_bytes if comm else 0,
                    "ring_steps": comm.ring_steps if comm else 0,
                    "resumed_from_step":
                        ckpt.resumed_from_step if ckpt else 0,
                    "spans": rec.export()}

        clean = False
        try:
            devs = self._local_devices(d["local_devices"], d["build_comm"])
            if d["build_comm"]:
                from repro.core.communicator import build_communicator
                shape = d["mesh_shape"] if d["n_parts"] == 1 else None
                with rec.span("comm_build"):
                    local = build_communicator(
                        devs, d["mesh_axes"], shape,
                        uid=f"task{uid}.p{part}",
                        placement=d.get("placement", ""))
                comm_s = local.build_seconds
            else:
                local = StubComm(devices=devs,
                                 placement=d.get("placement", ""))
            comm = ProcTaskComm(uid=uid, world_size=d["world_size"],
                                global_ranks=d["global_ranks"], part=part,
                                n_parts=d["n_parts"], local_comm=local,
                                hub=self.hub, attempt=attempt,
                                cancelled=cancelled,
                                placement=d.get("placement", ""),
                                peer_net=self.peer_net,
                                peer_addrs=d.get("peer_addrs"),
                                p2p_threshold=d.get("p2p_threshold", 1024),
                                raw_frames=d.get("raw_frames", True),
                                ring=d.get("ring", True),
                                shm=d.get("shm", True),
                                registry=_metrics.MetricsRegistry(
                                    parent=self.metrics))
            comm.checkpoint = ckpt
            # the recorder is bound to THIS thread for the payload call, so
            # nested library code (comm collectives, shuffle SpillBuffer)
            # records spans without any parameter plumbing
            with _spans.bound(rec):
                with rec.span("deserialize"):
                    fn, args, kwargs = serialize.loads(d["payload"])
                with rec.span("compute"):
                    res = fn(comm, *args, **kwargs)
            self.chan.send(protocol.PART_DONE, uid=uid, attempt=attempt,
                           part=part, result=serialize.dumps(res),
                           error=None, comm_build_s=comm_s, **stats())
            clean = True
        except ConnectionClosed:
            pass                     # parent is gone; nothing to report to
        except Exception as e:  # noqa: BLE001 — report any payload error
            try:
                self.chan.send(protocol.PART_DONE, uid=uid, attempt=attempt,
                               part=part, result=None,
                               error=f"{type(e).__name__}: {e}",
                               comm_build_s=comm_s, **stats())
            except ConnectionClosed:
                pass
        finally:
            self._tasks.pop((uid, attempt), None)
            self.hub.forget(uid, attempt)
            if self.peer_net is not None:
                # parked peer frames of this attempt are unclaimable now; a
                # failed/cancelled attempt also reclaims the shm segments
                # this part sent — its receivers abort without consuming
                self.peer_net.purge(uid, attempt,
                                    failed=not clean or cancelled.is_set())

    def _log(self, msg: str):
        print(f"[worker {self.worker_id} pid={os.getpid()} "
              f"t={time.time():.3f}] {msg}", file=sys.stderr, flush=True)

    # --- liveness ---------------------------------------------------------
    def _heartbeat_loop(self):
        while True:
            time.sleep(self.heartbeat)
            try:
                # every beat carries the gauge/counter snapshot plus a fresh
                # perf_counter stamp so the parent can place the telemetry
                # event on its own clock via the HELLO offset
                self.chan.send(protocol.HEARTBEAT, worker=self.worker_id,
                               t=time.time(),
                               perf_t=time.perf_counter(),
                               telemetry=self.metrics.snapshot())
            except ConnectionClosed as e:
                self._log(f"exiting: heartbeat send failed ({e})")
                os._exit(1)          # parent died: no reason to live on

    # --- main loop --------------------------------------------------------
    def run(self):
        data_addr = self.peer_net.data_addr if self.peer_net else None
        # perf_t is stamped as late as possible before the send: the parent
        # computes this worker's clock offset from it at HELLO receipt
        self.chan.send(protocol.HELLO, worker=self.worker_id, pid=os.getpid(),
                       n_devices=self.n_devices, token=self.token,
                       platform=sys.platform,
                       data_host=data_addr[0] if data_addr else None,
                       data_port=data_addr[1] if data_addr else None,
                       perf_t=time.perf_counter())
        threading.Thread(target=self._heartbeat_loop, daemon=True).start()
        while True:
            try:
                kind, d = self.chan.recv()
            except ConnectionClosed as e:
                self._log(f"exiting: parent channel closed ({e})")
                os._exit(1)
            if kind == protocol.LAUNCH:
                # stamp receipt so the part records the launch_recv span
                # (queueing delay between frame arrival and thread pickup)
                d["_recv_t"] = time.perf_counter()
                # register the cancel flag BEFORE the part thread exists so
                # a CANCEL racing the thread start is never lost (frames on
                # one channel are ordered: LAUNCH always precedes CANCEL)
                cancelled = threading.Event()
                self._tasks[(d["uid"], d["attempt"])] = cancelled
                threading.Thread(target=self._run_part, args=(d, cancelled),
                                 daemon=True).start()
            elif kind == protocol.COLL_RESULT:
                self.hub.deliver(d["uid"], d["attempt"], d["seq"],
                                 d["values"])
            elif kind == protocol.COLL_ERROR:
                self.hub.fail(d["uid"], d["attempt"], d.get("seq"),
                              d["error"])
            elif kind == protocol.CANCEL:
                cancelled = self._tasks.get((d["uid"], d["attempt"]))
                if cancelled is not None:    # part still running here
                    cancelled.set()
                    self.hub.fail(d["uid"], d["attempt"], None,
                                  "task cancelled")
            elif kind == protocol.PEERS_UPDATE:
                # elastic membership change: evict cached channels to the
                # departed peers NOW — not lazily on the next failed send
                # (which would cost a fallback).  Live addresses stay
                # per-task: every spanning LAUNCH ships its own book.
                if self.peer_net is not None:
                    for wid in d.get("removed", ()):
                        self.peer_net.evict(wid)
            elif kind == protocol.SHUTDOWN:
                self._log("exiting: shutdown requested")
                os._exit(0)


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--addr", required=True, help="host:port of the pilot")
    p.add_argument("--worker", required=True)
    p.add_argument("--n-devices", type=int, required=True)
    p.add_argument("--heartbeat", type=float, default=0.5)
    p.add_argument("--token", default="")
    p.add_argument("--p2p", type=int, default=1,
                   help="1: open a peer-data port (worker-to-worker "
                        "collective payloads); 0: hub relay only")
    a = p.parse_args(argv)
    host, port = a.addr.rsplit(":", 1)
    Worker((host, int(port)), a.worker, a.n_devices, a.heartbeat,
           a.token, p2p=bool(a.p2p)).run()


if __name__ == "__main__":
    main()
