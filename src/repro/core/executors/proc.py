"""ProcessExecutor: the multi-process pilot runtime (paper's multi-node mode).

One worker process per "node": a fresh interpreter launched with
``--xla_force_host_platform_device_count=K`` so it owns K host devices (the
pattern proven in ``tests/_subproc.py``).  The executor keeps a worker
registry whose combined device inventory — :class:`ProcDevice` handles
``worker:index`` — is what the scheduler's :class:`ResourceManager` carves
up, so ALL scheduling policy stays in ``SchedulerSession`` unchanged.

Task payloads are shipped as cloudpickle bytes over a length-prefixed socket
protocol (``protocol.py``).  A task whose ranks span several workers is split
into one *part* per worker; each part gets a :class:`ProcTaskComm` whose
local sub-mesh covers that worker's share and whose ``allgather``/``bcast``/
``barrier`` coordinate through the hub here — the paper's heterogeneous
communicator across nodes.  The task's result is part 0's (global rank 0)
return value.

Data plane vs control plane: each worker opens a peer-data listener and
advertises it in its HELLO; the parent ships the full address book (part ->
worker host:port) in every spanning LAUNCH, and collective payloads above
``p2p_threshold`` then move DIRECTLY between peer workers — the hub keeps
only the small per-collective control/barrier frame (and automatically
carries the payload again whenever a peer channel cannot be used, or when
``p2p=False`` / ``REPRO_P2P=0`` disables the plane).  ``hub_calls`` /
``hub_relay_bytes`` / ``p2p_bytes`` on the executor are the running
evidence.  Multi-HOST workers need nothing more than this address book —
the protocol is already plain TCP.

Liveness is real, not injected: workers heartbeat; an EOF/reset on a worker
channel or a stale heartbeat marks the worker lost, which surfaces as ONE
``device_failure`` ExecEvent naming the exact dead devices plus a ``fail``
event per task that had a part there — driving the scheduler's existing
retry-with-exclusion / pool-shrink logic with true process isolation.

The pilot is ELASTIC at runtime (the Radical-Pilot resize the paper leans
on): ``add_worker`` spawns a fresh interpreter mid-run, completes the same
HELLO handshake, pushes the refreshed peer address book to every live
worker (PEERS_UPDATE), and queues a ``grow`` ExecEvent so the scheduler
registers the new ``worker:index`` inventory and backfills pending work in
the same step; ``retire_worker`` is the graceful inverse — stop leasing,
drain in-flight parts (or fail them for retry-with-exclusion when
``immediate=True``), dismiss the process, and evict the retiree from the
survivors' peer-channel caches.  Worker ids are never reused.
"""
from __future__ import annotations

import itertools
import os
import secrets
import shutil
import signal
import socket
import subprocess
import sys
import tempfile
import threading
import time as _time
from pathlib import Path
from typing import NamedTuple, Optional, Sequence, Union

from repro.core.executors import protocol, serialize
from repro.core.executors import shm as _shmseg
from repro.core.executors.base import ExecEvent, QueueEventExecutor
from repro.core.executors.protocol import Channel, ConnectionClosed
from repro.core.pilot import ResourceManager
from repro.core.task import Task
from repro.obs import spans as _spans


class ProcDevice(NamedTuple):
    """One device slot owned by one worker process (hashable RM handle)."""
    worker: str
    index: int

    def __repr__(self):
        return f"{self.worker}:{self.index}"


class _WorkerHandle:
    def __init__(self, wid: str, proc: subprocess.Popen, n_devices: int,
                 log_path: Path):
        self.wid = wid
        self.proc = proc
        self.n_devices = n_devices
        self.log_path = log_path
        self.devices = tuple(ProcDevice(wid, i) for i in range(n_devices))
        self.chan: Optional[Channel] = None
        self.alive = False
        self.retiring = False    # graceful exit in progress: no new parts
        # may land here, but in-flight parts (and their hub collectives)
        # keep flowing until the drain completes
        self.last_hb = _time.monotonic()
        self.data_addr: Optional[tuple] = None   # (host, port) of the
        # worker's peer-data listener, from its HELLO; None when the peer
        # plane is disabled — the parent's address book entries
        self.clock_offset = 0.0   # parent perf_counter - worker perf_counter,
        # established at HELLO receipt (the worker stamps ``perf_t`` when it
        # sends); adding it shifts the worker's flight-recorder spans into
        # the parent clock — pure addition, order and nesting preserved

    def log_tail(self, n: int = 2000) -> str:
        try:
            return self.log_path.read_text(errors="replace")[-n:]
        except OSError:
            return "<no log>"


class _RawResult:
    """Still-serialized task result; materialized lazily in ``poll`` so the
    per-worker reader thread never stalls on a large deserialization."""
    __slots__ = ("data",)

    def __init__(self, data: bytes):
        self.data = data


class _Tracker:
    """In-flight task bookkeeping: which parts ran where, what came back.

    ``attempt`` disambiguates retries: the scheduler reuses ``task.uid``
    across attempts, so every frame carries (uid, attempt) and stale frames
    from a failed attempt can never be credited to its retry.

    The terminal event is delivered only once EVERY part is accounted for
    (result, error, or hosted-on-a-dead-worker): the scheduler releases the
    task's devices on that event, and a surviving sibling part may still be
    computing on its devices — releasing early would double-issue them."""

    def __init__(self, task: Task, part_workers: list, attempt: int):
        self.task = task
        self.part_workers = part_workers          # part index -> worker id
        self.attempt = attempt
        self.n_parts = len(part_workers)
        self.results: list = [None] * self.n_parts
        self.remaining = set(range(self.n_parts))
        self.error: Optional[str] = None          # first part error wins
        self.comm_build_s = 0.0
        self.delivered = False
        self.p2p_bytes = 0                        # summed over parts: bytes
        self.hub_calls = 0                        # moved peer-to-peer / hub
        # round-trips paid — the comm-stats evidence on the terminal event
        self.spills = 0                           # partitions spilled to disk
        self.p2p_fallbacks = 0                    # hub-relay fallbacks paid
        self.hub_relay_bytes = 0                  # payload bytes the hub
        # relayed for this task (accumulated hub-side in _coll_contribution)
        self.raw_coll_bytes = 0                   # collective bytes shipped
        self.shm_bytes = 0                        # with zero-copy framing /
        self.ring_steps = 0                       # through shm segments /
        # ring forwards performed — the transport-tier evidence per task
        self.resumed_from_step = 0                # max over parts: checkpoint
        # step a part restored before running (crash-safe resume evidence)
        self.spans: list = []                     # worker flight-recorder
        # spans, aligned into the parent clock — piggybacked per PART_DONE


class ProcessExecutor(QueueEventExecutor):
    """Pilot-side runtime over ``n_workers`` fresh worker interpreters.

    Usage::

        with ProcessExecutor(n_workers=2, devices_per_worker=2) as ex:
            rm = ex.resource_manager()
            sess = SchedulerSession(ex, rm)
            ...

    ``devices_per_worker`` may be an int (homogeneous nodes) or a sequence
    (heterogeneous inventory).  ``build_comm=False`` skips JAX mesh
    construction in the workers (scheduling tests on logical devices).
    ``extra_pythonpath`` entries are appended to the workers' PYTHONPATH so
    payload functions defined in e.g. a test module stay importable.
    """

    def __init__(self, n_workers: int = 2,
                 devices_per_worker: Union[int, Sequence[int]] = 2,
                 build_comm: bool = True, tick: float = 0.05,
                 heartbeat_interval: Optional[float] = None,
                 heartbeat: Optional[float] = None,
                 heartbeat_timeout: Optional[float] = None,
                 start_timeout: float = 120.0,
                 python: str = sys.executable,
                 env: Optional[dict] = None,
                 extra_pythonpath: Sequence[str] = (),
                 p2p: Optional[bool] = None,
                 p2p_threshold: int = 1024,
                 raw_frames: Optional[bool] = None,
                 ring: Optional[bool] = None,
                 shm: Optional[bool] = None):
        super().__init__()
        if isinstance(devices_per_worker, int):
            devices_per_worker = [devices_per_worker] * n_workers
        assert len(devices_per_worker) == n_workers
        self.build_comm = build_comm
        self.tick = tick
        # heartbeat cadence: explicit arg (``heartbeat`` and its historical
        # alias ``heartbeat_interval`` are equivalent) > REPRO_HEARTBEAT env
        # > 0.5s.  The liveness timeout defaults to 5 intervals (floor 2s):
        # a worker is declared hung only after missing that many consecutive
        # beats, so raising the interval proportionally slows failure
        # detection — set heartbeat_timeout explicitly to decouple them.
        hb = heartbeat if heartbeat is not None else heartbeat_interval
        if hb is None:
            hb = float(os.environ.get("REPRO_HEARTBEAT", "0.5"))
        self.hb_interval = hb
        self.hb_timeout = heartbeat_timeout or max(5 * hb, 2.0)
        self.start_timeout = start_timeout
        self.python = python
        self.env_override = dict(env or {})
        self.extra_pythonpath = list(extra_pythonpath)
        # peer data plane: None -> on unless REPRO_P2P=0 (the CI matrix
        # flips the env var to exercise the hub-relay fallback end to end)
        self.p2p = (os.environ.get("REPRO_P2P", "1") != "0") \
            if p2p is None else p2p
        self.p2p_threshold = p2p_threshold
        # raw-buffer peer framing (PEER_DATA_RAW) for the shuffle bucket
        # exchange: None -> on unless REPRO_RAW_FRAMES=0 (the A/B knob the
        # shuffle benchmark flips to measure pickled vs raw transport)
        self.raw_frames = (os.environ.get("REPRO_RAW_FRAMES", "1") != "0") \
            if raw_frames is None else raw_frames
        # ring allgather for wide (>= 4 part) tasks: None -> on unless
        # REPRO_RING=0 (tier A/B knob; direct all-to-all otherwise)
        self.ring = (os.environ.get("REPRO_RING", "1") != "0") \
            if ring is None else ring
        # same-host shared-memory payload handoff: None -> on unless
        # REPRO_SHM=0 (the CI matrix flips it so the tcp tiers stay
        # exercised end to end on single-host runners too)
        self.shm = (os.environ.get("REPRO_SHM", "1") != "0") \
            if shm is None else shm
        self.spills = 0         # shuffle partitions spilled to disk, summed
        # from the workers' PART_DONE accounting
        self.hub_calls = 0      # COLL round-trips served by this hub
        self.hub_relay_bytes = 0   # real payload bytes the hub relayed
        # (peer-mode collectives contribute only the tiny PEER_SENT marker)
        self.p2p_bytes = 0      # bytes moved worker-to-worker, summed from
        # the workers' PART_DONE accounting (the hub never sees these bytes)
        self.p2p_fallbacks = 0  # above-threshold payloads that fell back to
        # the hub relay, summed from the workers' PART_DONE accounting
        self.raw_coll_bytes = 0   # collective bytes shipped with zero-copy
        # raw framing (PEER_DATA_GEN frames + raw-layout shm segments)
        self.shm_bytes = 0      # payload bytes handed to same-host peers
        # through shared-memory segments (a subset of p2p_bytes)
        self.ring_steps = 0     # ring-allgather block forwards performed
        self._counts = list(devices_per_worker)
        self.workers: dict[str, _WorkerHandle] = {}
        self._running: dict[int, _Tracker] = {}
        self._attempts = itertools.count()
        self._coll: dict[tuple, dict] = {}  # (uid, attempt, seq) -> {part: b}
        self._lock = threading.Lock()
        self._started = False
        self._closed = False
        self._listener: Optional[socket.socket] = None
        self._logdir: Optional[Path] = None
        self._token: Optional[str] = None
        self._widx = len(self._counts)   # next elastic worker index: ids are
        # never reused, so a retired w1's stale state can't haunt a newcomer
        self._grow_lock = threading.Lock()   # serializes add_worker: the
        # registration accept loop matches HELLOs against ONE pending id

    # ------------------------------------------------------------------ #
    # lifecycle
    # ------------------------------------------------------------------ #
    def _worker_env(self, k: int) -> dict:
        env = dict(os.environ)
        flags = [f for f in env.get("XLA_FLAGS", "").split()
                 if not f.startswith("--xla_force_host_platform_device_count")]
        flags.append(f"--xla_force_host_platform_device_count={k}")
        env["XLA_FLAGS"] = " ".join(flags)
        # host devices only exist on the CPU platform; never let a worker
        # grab the parent's accelerator unless explicitly overridden
        env["JAX_PLATFORMS"] = "cpu"
        import repro
        src = str(Path(repro.__file__).resolve().parents[1])
        paths = [src, *self.extra_pythonpath]
        if env.get("PYTHONPATH"):
            paths.append(env["PYTHONPATH"])
        env["PYTHONPATH"] = os.pathsep.join(paths)
        env.update(self.env_override)
        return env

    def _spawn_worker(self, wid: str, k: int) -> _WorkerHandle:
        port = self._listener.getsockname()[1]
        log = self._logdir / f"{wid}.log"
        with open(log, "wb") as logf:   # Popen dups the fd; close ours
            proc = subprocess.Popen(
                [self.python, "-m", "repro.core.executors.worker",
                 "--addr", f"127.0.0.1:{port}", "--worker", wid,
                 "--n-devices", str(k),
                 "--heartbeat", str(self.hb_interval),
                 "--token", self._token,
                 "--p2p", "1" if self.p2p else "0"],
                env=self._worker_env(k), stdout=logf,
                stderr=subprocess.STDOUT)
        wh = _WorkerHandle(wid, proc, k, log)
        self.workers[wid] = wh
        return wh

    def _accept_hellos(self, pending: set, timeout: float):
        """Accept registrations on the pilot listener until every worker in
        ``pending`` completed its HELLO.  Raises RuntimeError (with the
        first culprit's log tail) on timeout or a worker dying first; the
        caller owns cleanup — start() kills the whole pilot, add_worker()
        reaps only the newcomer."""
        deadline = _time.monotonic() + timeout
        while pending:
            if _time.monotonic() > deadline:
                raise RuntimeError(
                    f"workers {sorted(pending)} did not register within "
                    f"{timeout}s; first log tail:\n"
                    f"{self.workers[sorted(pending)[0]].log_tail()}")
            for wid in list(pending):
                rc = self.workers[wid].proc.poll()
                if rc is not None:
                    raise RuntimeError(
                        f"worker {wid} exited rc={rc} during startup:\n"
                        f"{self.workers[wid].log_tail()}")
            try:
                sock, _ = self._listener.accept()
            except socket.timeout:
                continue
            except OSError as e:
                raise RuntimeError(f"pilot listener closed: {e}") from e
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            # accepted sockets are always blocking (they do not inherit the
            # listener's timeout); bound the handshake so a stray local
            # connection can neither hang startup nor crash it
            sock.settimeout(10.0)
            chan = Channel(sock)
            try:
                kind, d = chan.recv()
            except ConnectionClosed:
                chan.close()
                continue
            if kind != protocol.HELLO or d.get("token") != self._token or \
                    d.get("worker") not in pending:
                chan.close()
                continue
            sock.settimeout(None)
            wh = self.workers[d["worker"]]
            wh.chan, wh.alive = chan, True
            # clock alignment for the flight recorder: the worker stamped
            # its perf_counter as it sent HELLO; the difference (which
            # absorbs the one-way frame latency — microseconds on loopback)
            # maps every span the worker ships into this process's clock
            if d.get("perf_t") is not None:
                wh.clock_offset = _time.perf_counter() - d["perf_t"]
            if d.get("data_port"):
                wh.data_addr = (d.get("data_host") or "127.0.0.1",
                                d["data_port"])
            wh.last_hb = _time.monotonic()
            # byte progress counts as liveness: heartbeats queue behind any
            # large in-flight frame on the same stream
            def _touch(w=wh):
                w.last_hb = _time.monotonic()
            chan.on_traffic = _touch
            pending.discard(wh.wid)

    def start(self) -> "ProcessExecutor":
        if self._started:
            return self
        self._logdir = Path(tempfile.mkdtemp(prefix="repro-procexec-"))
        self._token = secrets.token_hex(8)
        lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lst.bind(("127.0.0.1", 0))
        lst.listen(max(len(self._counts), 4))
        lst.settimeout(1.0)
        self._listener = lst
        for i, k in enumerate(self._counts):
            self._spawn_worker(f"w{i}", k)
        try:
            self._accept_hellos(set(self.workers), self.start_timeout)
        except RuntimeError:
            self._kill_all()
            raise
        for wh in self.workers.values():
            threading.Thread(target=self._reader, args=(wh,),
                             daemon=True).start()
        threading.Thread(target=self._monitor, daemon=True).start()
        self._started = True
        return self

    def __enter__(self) -> "ProcessExecutor":
        return self.start()

    def __exit__(self, *exc):
        self.shutdown()

    def _kill_all(self):
        for wh in list(self.workers.values()):
            if wh.proc.poll() is None:
                wh.proc.kill()

    def shutdown(self, grace: float = 2.0):
        """Stop every worker (SHUTDOWN frame, then SIGKILL after ``grace``)."""
        self._closed = True
        for wh in list(self.workers.values()):
            if wh.alive and wh.chan is not None:
                try:
                    wh.chan.send(protocol.SHUTDOWN)
                except ConnectionClosed:
                    pass
            wh.alive = False
        deadline = _time.monotonic() + grace
        for wh in list(self.workers.values()):
            while wh.proc.poll() is None and _time.monotonic() < deadline:
                _time.sleep(0.02)
            if wh.proc.poll() is None:
                wh.proc.kill()
                wh.proc.wait()
            if wh.chan is not None:
                wh.chan.close()
        if self._listener is not None:
            self._listener.close()
        if self._logdir is not None:
            shutil.rmtree(self._logdir, ignore_errors=True)
            self._logdir = None
        self._sweep_segments()

    def _sweep_segments(self, wid: Optional[str] = None):
        """Remove ``/dev/shm`` residue of the shm transport tier.  Segments
        are named ``repro_{token8}_{creator_wid}_...``, so a dead or retired
        worker's leftovers (segments whose header never reached a receiver
        — the one cleanup the worker cannot do for itself after SIGKILL)
        are swept by its prefix; with no ``wid`` the whole pilot's prefix
        goes (shutdown safety net)."""
        if not self._token:
            return
        prefix = f"repro_{self._token[:8]}_"
        if wid is not None:
            prefix += f"{wid}_"
        _shmseg.sweep(prefix)

    def kill_worker(self, wid: str, sig: int = signal.SIGKILL):
        """Test/chaos hook: hard-kill one worker (true process isolation)."""
        self.workers[wid].proc.send_signal(sig)

    # ------------------------------------------------------------------ #
    # elasticity: grow and retire workers at runtime
    # ------------------------------------------------------------------ #
    def add_worker(self, devices_per_worker: Optional[int] = None,
                   timeout: Optional[float] = None) -> str:
        """Elastic grow: spawn ONE fresh worker interpreter mid-run and hand
        its inventory to the scheduler.

        The newcomer completes the normal HELLO handshake (including its
        peer-data port), the refreshed address book is pushed to every live
        worker (PEERS_UPDATE — subsequent spanning tasks can move payloads
        p2p to/from the new node), and a ``grow`` ExecEvent naming the exact
        new ``worker:index`` handles is queued for the scheduler core, which
        adds them to the live ResourceManager (``add_devices``), emits the
        ``grow`` trace event, and re-dispatches pending work in the same
        step.  ``Executor.topology`` needs no update call — it classifies by
        handle, so the placement layer sees the new node immediately.

        Returns the new worker id (e.g. ``"w2"``).  Ids are never reused.
        """
        self.start()
        if self._closed:
            raise RuntimeError("executor is shut down")
        k = devices_per_worker if devices_per_worker is not None else \
            (self._counts[0] if self._counts else 2)
        with self._grow_lock:
            wid = f"w{self._widx}"
            self._widx += 1
            wh = self._spawn_worker(wid, k)
            try:
                self._accept_hellos({wid}, timeout or self.start_timeout)
            except RuntimeError:
                self.workers.pop(wid, None)
                if wh.proc.poll() is None:
                    wh.proc.kill()
                    wh.proc.wait()
                raise
            self._counts.append(k)
            threading.Thread(target=self._reader, args=(wh,),
                             daemon=True).start()
        self._broadcast_peers()
        self._q.put(ExecEvent("grow", n_devices=k, devices=wh.devices))
        return wid

    def retire_worker(self, wid: str, immediate: bool = False,
                      drain_timeout: float = 120.0):
        """Elastic shrink, the graceful counterpart of a worker loss.

        Queues a ``retire`` ExecEvent FIRST (so the scheduler core stops
        leasing the worker's devices before it sees any later completion),
        then either *drains* — blocks until every in-flight part hosted on
        ``wid`` finished on its own, so no task loses results — or, with
        ``immediate=True``, fails the worker's in-flight parts now, driving
        the core's ordinary retry-with-exclusion onto the survivors (the
        retired inventory has already left the pool, so the retry cannot
        land back on it).  A drain that outlives ``drain_timeout`` escalates
        to the immediate path rather than wedging the caller.

        Either way the worker is then dismissed (SHUTDOWN, SIGKILL after a
        grace period), its channel closed, and the refreshed address book
        pushed to the survivors (PEERS_UPDATE) so their cached peer channels
        and mailboxes to the retiree are evicted — no per-payload fallback
        discovery, ``p2p_fallbacks`` stays 0 after a clean retire.  Unlike a
        crash, NO ``device_failure`` event is emitted."""
        wh = self.workers[wid]
        with self._lock:
            if not wh.alive or wh.retiring:
                return
            wh.retiring = True
        self._q.put(ExecEvent("retire", n_devices=wh.n_devices,
                              devices=wh.devices))
        if immediate:
            self._retire_parts(wid)
        else:
            deadline = _time.monotonic() + drain_timeout
            while wh.alive and self._busy_parts(wid):
                if _time.monotonic() > deadline:
                    self._retire_parts(wid)   # drain stuck: cut losses, the
                    break                     # retry lands on survivors
                _time.sleep(0.02)
        # dismiss the worker; its reader thread exits on the closed channel
        # and _worker_lost sees alive=False — a retire is not a failure
        wh.alive = False
        if wh.chan is not None:
            try:
                wh.chan.send(protocol.SHUTDOWN)
            except ConnectionClosed:
                pass
        deadline = _time.monotonic() + 2.0
        while wh.proc.poll() is None and _time.monotonic() < deadline:
            _time.sleep(0.02)
        if wh.proc.poll() is None:
            wh.proc.kill()
            wh.proc.wait()
        if wh.chan is not None:
            wh.chan.close()
        self._broadcast_peers(removed=(wid,))
        self._sweep_segments(wid)

    def _busy_parts(self, wid: str) -> bool:
        """True while any in-flight tracker still owes a part hosted on
        ``wid`` — the drain condition for a graceful retire."""
        with self._lock:
            return any(
                not t.delivered and any(
                    owner == wid and part in t.remaining
                    for part, owner in enumerate(t.part_workers))
                for t in self._running.values())

    def _retire_parts(self, wid: str):
        """Immediate retire: fail every in-flight part hosted on ``wid``.
        Sibling parts are aborted cooperatively (the usual partial-failure
        path) and the task's single fail event drives retry-with-exclusion
        on the surviving workers."""
        with self._lock:
            victims = [t for t in self._running.values()
                       if wid in t.part_workers and not t.delivered]
        for tracker in victims:
            for part, owner in enumerate(tracker.part_workers):
                if owner == wid:
                    self._part_terminal(tracker, part,
                                        error=f"worker {wid} retired")

    def _broadcast_peers(self, removed: Sequence[str] = ()):
        """Push the refreshed peer address book (PEERS_UPDATE) to every live
        worker after a membership change, naming departed ids so cached
        peer channels to a dead/retired worker are evicted promptly instead
        of being discovered per payload via the hub fallback."""
        # snapshot before iterating: a concurrent add_worker may resize the
        # dict mid-broadcast (this runs on monitor/reader threads too)
        handles = list(self.workers.values())
        book = {w.wid: w.data_addr for w in handles
                if w.alive and not w.retiring and w.data_addr is not None}
        for w in handles:
            if w.alive and w.chan is not None:
                try:
                    w.chan.send(protocol.PEERS_UPDATE, workers=book,
                                removed=list(removed))
                except ConnectionClosed:
                    pass

    # ------------------------------------------------------------------ #
    # inventory
    # ------------------------------------------------------------------ #
    def devices(self) -> tuple:
        """Current ProcDevice inventory, worker-major — feed to
        ResourceManager.  Retired and lost workers' handles are gone; a
        worker added at runtime contributes its handles the moment its
        HELLO completed."""
        self.start()
        # snapshot: add_worker inserts into the dict from another thread,
        # and dict iteration concurrent with a resize raises RuntimeError
        return tuple(d for wh in list(self.workers.values())
                     if wh.alive and not wh.retiring for d in wh.devices)

    def resource_manager(self) -> ResourceManager:
        return ResourceManager(self.devices())

    def topology(self, devices):
        """One node per worker interpreter: a ``ProcDevice`` lives on node
        ``worker``.  This is the report the pack policy uses to keep a
        fitting task's ranks inside ONE worker — a single local sub-mesh,
        zero parent-hub collectives."""
        from repro.core.placement import Topology
        nodes: dict = {}
        for d in devices:
            nodes.setdefault(getattr(d, "worker", "node0"), []).append(d)
        return Topology(nodes)

    # ------------------------------------------------------------------ #
    # Executor interface (now comes from QueueEventExecutor)
    # ------------------------------------------------------------------ #
    def poll(self, timeout: Optional[float]) -> Optional[ExecEvent]:
        ev = super().poll(timeout)
        if ev is not None and isinstance(ev.result, _RawResult):
            try:
                ev.result = serialize.loads(ev.result.data)
            except Exception as e:  # noqa: BLE001 — undeserializable result
                ev.kind, ev.result = "fail", None
                ev.error = f"{type(e).__name__}: {e}"
        return ev

    def launch(self, task: Task, duration_hint: Optional[float] = None):
        self.start()
        parts: dict[str, dict] = {}
        for rank, dev in enumerate(task.devices):
            p = parts.setdefault(dev.worker,
                                 {"local_devices": [], "global_ranks": []})
            p["local_devices"].append(dev.index)
            p["global_ranks"].append(rank)
        part_workers = list(parts)
        tracker = _Tracker(task, part_workers, next(self._attempts))
        with self._lock:
            self._running[task.uid] = tracker
        if task.desc.mesh_shape and tracker.n_parts > 1:
            # a worker-local sub-mesh cannot honour a task-wide topology;
            # fail loudly instead of silently auto-factoring each part
            self._fail_all_parts(
                tracker, f"task {task.desc.name!r}: mesh_shape="
                f"{task.desc.mesh_shape} cannot be honoured when ranks span "
                f"{tracker.n_parts} workers; omit mesh_shape or pack the "
                f"task into one worker")
            return
        dead = [w for w in part_workers
                if not self.workers[w].alive or self.workers[w].retiring]
        if dead:
            # lost before launch, or racing a retire that the scheduler has
            # not absorbed yet: fail fast so the ordinary retry re-places
            # the task on the remaining pool
            self._fail_all_parts(
                tracker, f"worker {dead[0]} unavailable before launch")
            return
        try:
            payload = serialize.dumps(
                (task.desc.fn, task.desc.args, task.desc.kwargs))
        except Exception as e:  # noqa: BLE001 — unserializable payload
            self._fail_all_parts(tracker, f"{type(e).__name__}: {e}")
            return
        # the address book: every part's worker identity + peer-data address,
        # shipped with every spanning LAUNCH so large collective payloads can
        # move worker-to-worker (a None entry downgrades the whole task to
        # hub relay — the sentinel contract needs every part reachable)
        peer_addrs = None
        if self.p2p and tracker.n_parts > 1:
            peer_addrs = [
                (w, *self.workers[w].data_addr)
                if self.workers[w].data_addr else None
                for w in part_workers]
        for idx, wid in enumerate(part_workers):
            p = parts[wid]
            try:
                self.workers[wid].chan.send(
                    protocol.LAUNCH, uid=task.uid, attempt=tracker.attempt,
                    name=task.desc.name,
                    part=idx, n_parts=tracker.n_parts,
                    local_devices=p["local_devices"],
                    global_ranks=p["global_ranks"],
                    world_size=task.desc.ranks, payload=payload,
                    mesh_axes=task.desc.mesh_axes,
                    mesh_shape=task.desc.mesh_shape,
                    build_comm=self.build_comm,
                    placement=task.placement,
                    peer_addrs=peer_addrs,
                    p2p_threshold=self.p2p_threshold,
                    raw_frames=self.raw_frames,
                    ring=self.ring, shm=self.shm,
                    ckpt_dir=task.ckpt_dir,
                    ckpt_attempt=task.ckpt_attempt)
            except ConnectionClosed:
                # this part (and the never-launched rest) can't run; parts
                # already launched on other workers complete the tracker
                # with their own PART_DONEs
                for missing in range(idx, tracker.n_parts):
                    self._part_terminal(
                        tracker, missing,
                        error=f"worker {wid} lost at launch")
                self._worker_lost(wid, "connection lost at launch")
                return

    def cancel(self, task: Task) -> bool:
        with self._lock:
            tracker = self._running.get(task.uid)
        if tracker is None:
            return True          # nothing in flight: no event will come
        for wid in tracker.part_workers:
            wh = self.workers.get(wid)
            if wh is not None and wh.alive:
                try:
                    wh.chan.send(protocol.CANCEL, uid=task.uid,
                                 attempt=tracker.attempt)
                except ConnectionClosed:
                    pass
        return False             # cooperative: the completion event still
        # arrives (possibly as a fail) and the core reclaims devices then

    # ------------------------------------------------------------------ #
    # worker I/O
    # ------------------------------------------------------------------ #
    def _reader(self, wh: _WorkerHandle):
        while wh.alive:
            try:
                kind, d = wh.chan.recv()
            except ConnectionClosed as e:
                self._worker_lost(wh.wid, f"connection lost ({e})")
                return
            wh.last_hb = _time.monotonic()   # any traffic proves liveness
            if kind == protocol.PART_DONE:
                self._part_done(wh, d)
            elif kind == protocol.COLL:
                self._coll_contribution(wh, d)
            elif kind == protocol.HEARTBEAT and d.get("telemetry"):
                # telemetry-carrying heartbeat: surface the gauge snapshot
                # as an ExecEvent so the scheduler records a ``telemetry``
                # trace event; stamped in the parent clock via the offset
                rec = dict(d["telemetry"])
                if d.get("perf_t") is not None:
                    rec["t"] = d["perf_t"] + wh.clock_offset
                self._q.put(ExecEvent("telemetry", worker=wh.wid,
                                      telemetry=rec))

    def _monitor(self):
        while not self._closed:
            _time.sleep(self.hb_interval)
            for wh in list(self.workers.values()):
                if not wh.alive:
                    continue
                rc = wh.proc.poll()
                if rc is not None:
                    self._worker_lost(wh.wid, f"process exited rc={rc}")
                elif _time.monotonic() - wh.last_hb > self.hb_timeout:
                    wh.proc.kill()   # hung, not just slow: enforce isolation
                    self._worker_lost(
                        wh.wid, f"heartbeat timeout (> {self.hb_timeout}s)")

    # ------------------------------------------------------------------ #
    # completion / failure plumbing
    # ------------------------------------------------------------------ #
    def _abort_parts(self, tracker: _Tracker, error: str):
        """Prompt-unblock the surviving parts of a failing task: cooperative
        CANCEL plus a hub release so a part blocked in a collective raises
        now instead of waiting out the collective timeout.  The parts keep
        their devices until they actually finish (their PART_DONE completes
        the tracker) — releasing earlier would double-issue busy devices."""
        for wid in dict.fromkeys(tracker.part_workers):
            wh = self.workers.get(wid)
            if wh is not None and wh.alive:
                try:
                    wh.chan.send(protocol.CANCEL, uid=tracker.task.uid,
                                 attempt=tracker.attempt)
                    wh.chan.send(protocol.COLL_ERROR, uid=tracker.task.uid,
                                 attempt=tracker.attempt, seq=None,
                                 error=error)
                except ConnectionClosed:
                    pass

    def _part_terminal(self, tracker: _Tracker, part: int,
                       error: Optional[str] = None, result=None,
                       comm_s: float = 0.0, p2p_bytes: int = 0,
                       hub_calls: int = 0, spills: int = 0,
                       p2p_fallbacks: int = 0, raw_coll_bytes: int = 0,
                       shm_bytes: int = 0, ring_steps: int = 0,
                       resumed_from_step: int = 0, spans=()):
        """Record one part's fate; the task's single terminal ExecEvent is
        delivered only when EVERY part is accounted for (result, error, or
        hosted on a dead worker)."""
        with self._lock:
            if tracker.delivered or part not in tracker.remaining:
                return
            tracker.remaining.discard(part)
            tracker.results[part] = result
            tracker.comm_build_s = max(tracker.comm_build_s, comm_s)
            tracker.p2p_bytes += p2p_bytes
            tracker.hub_calls += hub_calls
            tracker.spills += spills
            tracker.p2p_fallbacks += p2p_fallbacks
            tracker.raw_coll_bytes += raw_coll_bytes
            tracker.shm_bytes += shm_bytes
            tracker.ring_steps += ring_steps
            tracker.resumed_from_step = max(tracker.resumed_from_step,
                                            resumed_from_step)
            tracker.spans.extend(spans)
            self.p2p_bytes += p2p_bytes
            self.spills += spills
            self.p2p_fallbacks += p2p_fallbacks
            self.raw_coll_bytes += raw_coll_bytes
            self.shm_bytes += shm_bytes
            self.ring_steps += ring_steps
            first_error = error is not None and tracker.error is None
            if first_error:
                tracker.error = error
            complete = not tracker.remaining
            if complete:
                tracker.delivered = True
                self._running.pop(tracker.task.uid, None)
                for k in [k for k in self._coll if k[0] == tracker.task.uid]:
                    del self._coll[k]
        if first_error and not complete:
            self._abort_parts(tracker, error)
        if not complete:
            return
        if tracker.error is not None:
            self._q.put(ExecEvent("fail", task=tracker.task,
                                  error=tracker.error,
                                  comm_build_s=tracker.comm_build_s,
                                  p2p_bytes=tracker.p2p_bytes,
                                  hub_calls=tracker.hub_calls,
                                  spills=tracker.spills,
                                  p2p_fallbacks=tracker.p2p_fallbacks,
                                  hub_relay_bytes=tracker.hub_relay_bytes,
                                  raw_coll_bytes=tracker.raw_coll_bytes,
                                  shm_bytes=tracker.shm_bytes,
                                  ring_steps=tracker.ring_steps,
                                  resumed_from_step=tracker.resumed_from_step,
                                  spans=list(tracker.spans)))
        else:
            # results stay as bytes until poll(): deserializing a large
            # result here would stall this reader thread past hb_timeout
            # and get a healthy worker killed as hung
            self._q.put(ExecEvent("done", task=tracker.task,
                                  result=_RawResult(tracker.results[0]),
                                  comm_build_s=tracker.comm_build_s,
                                  p2p_bytes=tracker.p2p_bytes,
                                  hub_calls=tracker.hub_calls,
                                  spills=tracker.spills,
                                  p2p_fallbacks=tracker.p2p_fallbacks,
                                  hub_relay_bytes=tracker.hub_relay_bytes,
                                  raw_coll_bytes=tracker.raw_coll_bytes,
                                  shm_bytes=tracker.shm_bytes,
                                  ring_steps=tracker.ring_steps,
                                  resumed_from_step=tracker.resumed_from_step,
                                  spans=list(tracker.spans)))

    def _fail_all_parts(self, tracker: _Tracker, error: str):
        """Abort a launch that never (fully) reached the workers."""
        for part in range(tracker.n_parts):
            self._part_terminal(tracker, part, error=error)

    def _part_done(self, wh: _WorkerHandle, d: dict):
        with self._lock:
            tracker = self._running.get(d["uid"])
        if tracker is None or tracker.attempt != d["attempt"]:
            return       # stale: task already failed/cancelled, or this part
            # belongs to a previous attempt of a retried task (same uid)
        self._part_terminal(tracker, d["part"], error=d["error"],
                            result=d["result"], comm_s=d["comm_build_s"],
                            p2p_bytes=d.get("p2p_bytes", 0),
                            hub_calls=d.get("hub_calls", 0),
                            spills=d.get("spills", 0),
                            p2p_fallbacks=d.get("p2p_fallbacks", 0),
                            raw_coll_bytes=d.get("raw_coll_bytes", 0),
                            shm_bytes=d.get("shm_bytes", 0),
                            ring_steps=d.get("ring_steps", 0),
                            resumed_from_step=d.get("resumed_from_step", 0),
                            spans=_spans.align(
                                d.get("spans") or (), wh.clock_offset,
                                worker=wh.wid, part=d["part"], uid=d["uid"],
                                task=tracker.task.desc.name))

    def _coll_contribution(self, sender: _WorkerHandle, d: dict):
        uid, attempt, seq = d["uid"], d["attempt"], d["seq"]
        with self._lock:
            # counter updates stay under the lock: += from concurrent
            # per-worker reader threads would drop updates
            self.hub_calls += 1
            relayed = 0 if d["payload"] == protocol.PEER_SENT \
                else len(d["payload"])
            self.hub_relay_bytes += relayed
            tracker = self._running.get(uid)
            if tracker is None or tracker.delivered or \
                    tracker.attempt != attempt:
                tracker = None
            else:
                # only the hub sees relayed bytes, so the per-task evidence
                # is accumulated here rather than on the workers' PART_DONE
                tracker.hub_relay_bytes += relayed
                entry = self._coll.setdefault((uid, attempt, seq), {})
                entry[d["part"]] = d["payload"]
                ready = len(entry) == tracker.n_parts
                if ready:
                    values = [entry[i] for i in range(tracker.n_parts)]
                    del self._coll[(uid, attempt, seq)]
        if tracker is None:      # aborted task or stale attempt: release the
            try:                 # sender's waiting thread
                sender.chan.send(protocol.COLL_ERROR, uid=uid,
                                 attempt=attempt, seq=seq,
                                 error="task aborted")
            except ConnectionClosed:
                pass
            return
        if ready:
            for wid in tracker.part_workers:
                wh = self.workers.get(wid)
                if wh is not None and wh.alive:
                    try:
                        wh.chan.send(protocol.COLL_RESULT, uid=uid,
                                     attempt=attempt, seq=seq, values=values)
                    except ConnectionClosed:
                        pass

    def _worker_lost(self, wid: str, reason: str):
        with self._lock:
            wh = self.workers[wid]
            if not wh.alive:
                return
            wh.alive = False
            victims = [t for t in self._running.values()
                       if wid in t.part_workers and not t.delivered]
        if wh.chan is not None:
            wh.chan.close()
        if wh.proc.poll() is None:
            wh.proc.kill()       # half-dead worker: finish the job
        # one pool-shrink event naming the exact dead inventory, then the
        # dead worker's parts are marked terminal — each victim task's fail
        # event goes out once its surviving parts also finish (they hold
        # their devices until then), driving device exclusion + retry on
        # the surviving workers
        self._q.put(ExecEvent("device_failure", n_devices=wh.n_devices,
                              devices=wh.devices))
        for tracker in victims:
            for part, owner in enumerate(tracker.part_workers):
                if owner == wid:
                    self._part_terminal(tracker, part,
                                        error=f"worker {wid} lost: {reason}")
        # survivors evict their cached peer channels to the dead worker now,
        # not on their next (doomed) send to it
        self._broadcast_peers(removed=(wid,))
        # reclaim /dev/shm segments the dead worker created but nobody will
        # consume (its receivers abort; the header may never have shipped)
        self._sweep_segments(wid)
