"""Live in-process executor: one worker thread + private communicator per
task on real JAX devices."""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Optional

from repro.core.executors.base import ExecEvent, QueueEventExecutor
from repro.core.task import Task


@dataclasses.dataclass
class StubComm:
    """Communicator stand-in when ``ThreadExecutor(build_comm=False)`` — used
    by tests that exercise scheduling on fake devices without JAX meshes."""
    devices: tuple
    mesh: Any = None
    build_seconds: float = 0.0
    placement: str = ""          # policy that placed the devices (pack|spread)
    p2p_bytes: int = 0           # uniform comm-stats surface: an in-process
    hub_calls: int = 0           # comm never pays a hub or peer transfer
    spills: int = 0              # nor spills shuffle partitions to disk
    raw_coll_bytes: int = 0      # nor ships raw/shm frames or forwards
    shm_bytes: int = 0           # ring blocks — constant zeros keep the
    ring_steps: int = 0          # transport counters uniform across backends
    checkpoint: Any = None       # CheckpointContext when the session runs
    # with a checkpoint root (REPRO_CKPT_DIR); None otherwise

    @property
    def size(self) -> int:
        return len(self.devices)


class ThreadExecutor(QueueEventExecutor):
    """Live executor: each task runs ``fn(comm, *args, **kwargs)`` in a
    worker thread on its allocated devices, with a freshly built private
    Communicator (the paper's per-task MPI_Comm analogue)."""

    def __init__(self, build_comm: bool = True, tick: float = 0.05):
        super().__init__()
        self.build_comm = build_comm
        self.tick = tick

    def launch(self, task: Task, duration_hint: Optional[float] = None):
        def worker():
            comm_s = 0.0
            ckpt = None
            if task.ckpt_dir:
                # in-process tasks always run as one part, so the p0-of-1
                # scope interoperates with single-part proc attempts
                from repro.train.checkpoint import CheckpointContext
                ckpt = CheckpointContext(task.ckpt_dir,
                                         attempt=task.ckpt_attempt or "a0")
            try:
                if self.build_comm:
                    from repro.core.communicator import build_communicator
                    comm = build_communicator(task.devices,
                                              task.desc.mesh_axes,
                                              task.desc.mesh_shape,
                                              uid=f"task{task.uid}",
                                              placement=task.placement)
                    comm_s = comm.build_seconds
                else:
                    comm = StubComm(devices=tuple(task.devices),
                                    placement=task.placement)
                comm.checkpoint = ckpt
                res = task.desc.fn(comm, *task.desc.args, **task.desc.kwargs)
                self._q.put(ExecEvent(
                    "done", task=task, result=res, comm_build_s=comm_s,
                    resumed_from_step=ckpt.resumed_from_step if ckpt else 0))
            except Exception as e:  # noqa: BLE001 — report any payload error
                self._q.put(ExecEvent(
                    "fail", task=task, error=f"{type(e).__name__}: {e}",
                    comm_build_s=comm_s,
                    resumed_from_step=ckpt.resumed_from_step if ckpt else 0))

        threading.Thread(target=worker, daemon=True).start()
