"""Payload serialization for cross-process task shipping.

Two layers are deliberately kept apart:

* *protocol framing* (``protocol.py``) pickles only plain control dicts
  (strings, ints, bytes) with the stdlib pickler — version-stable and cheap.
* *payload serialization* (this module) carries the user's ``fn``/args/
  results, which may be closures or lambdas.  cloudpickle handles those by
  value; when it is absent we fall back to stdlib pickle, which restricts
  payloads to importable module-level functions (the error message says so).
"""
from __future__ import annotations

import pickle

try:
    import cloudpickle as _cp
    HAVE_CLOUDPICKLE = True
except ImportError:          # pragma: no cover - depends on environment
    _cp = None
    HAVE_CLOUDPICKLE = False


def _reject_main_refs(obj, depth: int = 2):
    """Stdlib pickle serializes a __main__-defined function BY REFERENCE,
    which dumps fine here but explodes with an opaque AttributeError inside
    the worker (whose __main__ is the worker module).  Catch the common
    shapes — the payload tuple's functions/objects — at dump time with an
    actionable error instead."""
    mod = getattr(obj, "__module__", None) or \
        getattr(type(obj), "__module__", None)
    if mod == "__main__":
        raise TypeError(
            f"task payload {obj!r} is defined in __main__ and cannot be "
            f"shipped to a worker process by stdlib pickle; install "
            f"cloudpickle or move it to an importable module")
    if depth and isinstance(obj, (tuple, list)):
        for item in obj:
            _reject_main_refs(item, depth - 1)
    elif depth and isinstance(obj, dict):
        for item in obj.values():
            _reject_main_refs(item, depth - 1)


def dumps(obj) -> bytes:
    if HAVE_CLOUDPICKLE:
        return _cp.dumps(obj)
    _reject_main_refs(obj)
    try:
        return pickle.dumps(obj)
    except Exception as e:
        raise TypeError(
            f"cannot serialize task payload without cloudpickle "
            f"({type(obj).__name__}: {e}); install cloudpickle or use "
            f"importable module-level functions") from e


def loads(data: bytes):
    # cloudpickle output is plain pickle on the wire; stdlib loads both
    return pickle.loads(data)


# --- array-leaf splitting (zero-copy collective framing) --------------------
#
# A collective payload is usually a container whose big leaves are numpy/JAX
# arrays and whose everything-else is small.  ``dumps_arrays`` splits such a
# payload into a tiny pickled *skeleton* (the container structure with each
# array leaf replaced by an :class:`_ArrayRef`) plus the arrays' contiguous
# buffers, which the transport ships as raw bytes — no pickle pass over the
# MB-scale body.  ``loads_arrays`` reverses it with zero-copy
# ``np.frombuffer`` views.  Payloads with no array leaves return ``None``
# from ``dumps_arrays`` so callers take the plain pickled path.


class _ArrayRef:
    """Skeleton placeholder for an extracted array leaf; ``i`` indexes the
    side-channel buffer list.  Stdlib-picklable on purpose: skeletons must
    decode even without cloudpickle."""

    __slots__ = ("i",)

    def __init__(self, i: int):
        self.i = i

    def __reduce__(self):
        return (_ArrayRef, (self.i,))


def _as_array(leaf):
    """``leaf`` as a C-contiguous ndarray when it is raw-shippable (numpy or
    JAX array of a non-object dtype), else None.  Detection is type-based —
    lists/scalars/bytes must never be promoted to arrays, or the round trip
    would change the payload's types."""
    import numpy as np
    if isinstance(leaf, np.ndarray):
        a = leaf
    elif (type(leaf).__module__.split(".", 1)[0] in ("jax", "jaxlib")
          and hasattr(leaf, "__array__")):
        a = np.asarray(leaf)
    else:
        return None
    if a.dtype.hasobject:
        return None                  # object arrays still need pickle
    return np.ascontiguousarray(a)


def _split(obj, bufs: list):
    a = _as_array(obj)
    if a is not None:
        bufs.append(a)
        return _ArrayRef(len(bufs) - 1)
    t = type(obj)
    # walk only the exact builtin containers: subclasses (namedtuples,
    # OrderedDicts with semantics, user types) stay opaque pickled leaves
    if t is dict:
        return {k: _split(v, bufs) for k, v in obj.items()}
    if t is list:
        return [_split(v, bufs) for v in obj]
    if t is tuple:
        return tuple(_split(v, bufs) for v in obj)
    return obj


def _join(obj, arrs: list):
    if isinstance(obj, _ArrayRef):
        return arrs[obj.i]
    t = type(obj)
    if t is dict:
        return {k: _join(v, arrs) for k, v in obj.items()}
    if t is list:
        return [_join(v, arrs) for v in obj]
    if t is tuple:
        return tuple(_join(v, arrs) for v in obj)
    return obj


def dumps_arrays(obj):
    """Split ``obj`` into ``(skeleton_bytes, metas, bufs)`` where ``metas``
    is ``[(dtype_str, shape), ...]`` and ``bufs`` the matching contiguous
    arrays whose raw bytes follow the header on the wire.  Returns ``None``
    when the payload holds no array leaves — plain pickle is then both
    simpler and cheaper."""
    bufs: list = []
    skel = _split(obj, bufs)
    if not bufs:
        return None
    metas = [(a.dtype.str, a.shape) for a in bufs]
    return dumps(skel), metas, bufs


def loads_arrays(skel_bytes: bytes, metas, payload):
    """Inverse of :func:`dumps_arrays` given the received body ``payload``
    (the buffers concatenated in ``metas`` order).  Array leaves come back
    as read-only ``np.frombuffer`` views aliasing ``payload`` — callers
    that mutate must copy first (same contract as the shuffle frames)."""
    import numpy as np
    arrs, off = [], 0
    for dtype, shape in metas:
        dt = np.dtype(dtype)
        count = 1
        for s in shape:
            count *= int(s)
        arrs.append(np.frombuffer(payload, dt, count=count,
                                  offset=off).reshape(shape))
        off += dt.itemsize * count
    return _join(loads(skel_bytes), arrs)


def copy_local(obj):
    """Deep copy with the exact semantics of ``loads(dumps(obj))`` — the
    result never aliases the input — but without pickling array bytes:
    array leaves short-circuit through ``np.array`` (a writable copy) and
    only the small skeleton round-trips through pickle.  This is the
    single-part collective path, the hottest pack-placement overhead."""
    import numpy as np
    bufs: list = []
    skel = _split(obj, bufs)
    if not bufs:
        return loads(dumps(obj))
    return _join(loads(dumps(skel)), [np.array(a) for a in bufs])
