"""Payload serialization for cross-process task shipping.

Two layers are deliberately kept apart:

* *protocol framing* (``protocol.py``) pickles only plain control dicts
  (strings, ints, bytes) with the stdlib pickler — version-stable and cheap.
* *payload serialization* (this module) carries the user's ``fn``/args/
  results, which may be closures or lambdas.  cloudpickle handles those by
  value; when it is absent we fall back to stdlib pickle, which restricts
  payloads to importable module-level functions (the error message says so).
"""
from __future__ import annotations

import pickle

try:
    import cloudpickle as _cp
    HAVE_CLOUDPICKLE = True
except ImportError:          # pragma: no cover - depends on environment
    _cp = None
    HAVE_CLOUDPICKLE = False


def _reject_main_refs(obj, depth: int = 2):
    """Stdlib pickle serializes a __main__-defined function BY REFERENCE,
    which dumps fine here but explodes with an opaque AttributeError inside
    the worker (whose __main__ is the worker module).  Catch the common
    shapes — the payload tuple's functions/objects — at dump time with an
    actionable error instead."""
    mod = getattr(obj, "__module__", None) or \
        getattr(type(obj), "__module__", None)
    if mod == "__main__":
        raise TypeError(
            f"task payload {obj!r} is defined in __main__ and cannot be "
            f"shipped to a worker process by stdlib pickle; install "
            f"cloudpickle or move it to an importable module")
    if depth and isinstance(obj, (tuple, list)):
        for item in obj:
            _reject_main_refs(item, depth - 1)
    elif depth and isinstance(obj, dict):
        for item in obj.values():
            _reject_main_refs(item, depth - 1)


def dumps(obj) -> bytes:
    if HAVE_CLOUDPICKLE:
        return _cp.dumps(obj)
    _reject_main_refs(obj)
    try:
        return pickle.dumps(obj)
    except Exception as e:
        raise TypeError(
            f"cannot serialize task payload without cloudpickle "
            f"({type(obj).__name__}: {e}); install cloudpickle or use "
            f"importable module-level functions") from e


def loads(data: bytes):
    # cloudpickle output is plain pickle on the wire; stdlib loads both
    return pickle.loads(data)
