"""Continuous-batching serving: a slotted KV cache that never drains.

The static engine (``repro.serve.engine.ServeEngine``) runs prefill + decode
per prompt-length group: the decode batch starts full, bleeds slots as short
requests finish, and fully drains before the next group is admitted.  This
engine keeps ONE decode batch alive for the lifetime of the server:

* the KV cache is allocated once for ``max_batch`` *slots* over a shared
  ``max_seq`` sequence budget;
* a finished sequence frees its slot immediately;
* a queued request is admitted into a free slot *between decode steps* — its
  prompt is prefilled into a single-slot cache and scattered into the shared
  cache at the slot index — so the running batch is re-filled mid-decode and
  the decode loop never restarts from an empty batch.

The cache layout is probed, not assumed: every model family exposes
``cache_init``/``prefill``/``decode_step`` with its own cache pytree
(attention KV, Mamba conv/ssm state, cross-attention KV...), and
:func:`cache_batch_axes` locates the batch axis of every leaf by comparing
``jax.eval_shape`` of the prefill output at two batch sizes — the one axis
whose size tracks the batch size.  Admission is then a per-leaf
``dynamic_update_slice_in_dim`` along that axis, identical for all ten
archs.

Per-slot correctness mirrors the static engine exactly: each slot keeps its
own write position, ``decode_step`` masks attention per element by
``positions + 1``, and free slots decode a dummy token whose garbage cache
writes are overwritten wholesale by the next admission — so a request's
token stream is bit-identical to ``greedy_reference`` regardless of what the
neighbouring slots are doing (asserted under staggered admission in
tests/test_serve_continuous.py).

Observability: counters (``serve_admitted`` / ``serve_completed`` /
``serve_evicted`` / ``serve_decode_steps`` / ``serve_prefill_tokens``) and
gauges (``serve_queue_depth`` / ``serve_slots_active``) live in a
:class:`repro.obs.MetricsRegistry`; ``ServeDriver`` surfaces snapshots as
``telemetry`` TraceEvents and feeds the autoscaler from them.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.models.attention import AttnMode
from repro.obs.metrics import MetricsRegistry
from repro.serve.engine import Request, modal_dummy_inputs, prompt_prefix_len


def cache_batch_axes(cfg: ModelConfig, params, max_seq: int):
    """Locate the batch axis of every prefill-cache leaf.

    Probes ``prefill`` abstractly (``jax.eval_shape`` — no FLOPs, no
    allocation) at batch sizes 2 and 3: only the batch dimension depends on
    the batch size, so exactly one axis per leaf may differ.  Returns
    ``(axes_tree, cache_shape_tree)`` where ``cache_shape_tree`` is the
    per-request (batch=1 along the batch axis) leaf spec at batch size 2 —
    the dtypes are the ones ``prefill`` actually produces, which is what
    ``decode_step`` must keep seeing for bit-identity with the static path
    (``cache_init`` dtypes can legitimately differ, e.g. fp32 SSM carries).
    """
    api = registry.get_model(cfg)

    def probe(b):
        batch = {"tokens": jnp.zeros((b, 1), jnp.int32),
                 **modal_dummy_inputs(cfg, b)}
        cache, _ = jax.eval_shape(
            lambda p, bt: api.prefill(p, cfg, bt, max_seq, AttnMode()),
            params, batch)
        return cache

    c2, c3 = probe(2), probe(3)

    def axis(a, b):
        diffs = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                 if x != y]
        if len(diffs) != 1:
            raise ValueError(
                f"cannot locate batch axis: shapes {a.shape} vs {b.shape} "
                f"differ in {len(diffs)} axes (family {cfg.family!r})")
        return diffs[0]

    return jax.tree.map(axis, c2, c3), c2


@dataclasses.dataclass
class _Slot:
    """One active sequence: its request, write position, and progress."""
    req: Request
    position: int       # next KV write index (prefix + prompt_len + decoded)
    next_tok: int       # last generated token = next decode input
    generated: list     # tokens generated so far (next_tok included)

    @property
    def remaining(self) -> int:
        return self.req.max_new_tokens - len(self.generated)


@dataclasses.dataclass
class Admission:
    """A prefilled request ready to be inserted into a slot: the single-slot
    cache plus the first generated token (from the prefill logits).  Pure
    output of :meth:`ContinuousEngine.prefill_request` — computing one does
    not touch the shared cache, so prefill work can run concurrently with
    decode rounds (the ServeDriver's task split)."""
    req: Request
    cache: object       # prefill cache pytree, batch size 1
    first_tok: int


class ContinuousEngine:
    """Continuous-batching greedy generation over a slotted KV cache.

    Shared-state methods (``insert``, ``decode_round``, ``step``, ``run``)
    must be called from one control thread at a time; ``submit`` and
    ``prefill_request`` touch only the queue / their own arrays.
    """

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256,
                 metrics: Optional[MetricsRegistry] = None):
        self.cfg = cfg
        self.params = params
        self.api = registry.get_model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._prefix = prompt_prefix_len(cfg)
        self._decode = jax.jit(
            lambda p, b, c: self.api.decode_step(p, cfg, b, c))
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, cfg, b, max_seq, AttnMode()))
        axes, spec1 = cache_batch_axes(cfg, params, max_seq)
        self._axes = axes
        # the shared slot cache: prefill's own layout/dtypes, batch axis
        # widened to max_batch slots
        self.cache = jax.tree.map(
            lambda s, ax: jnp.zeros(
                s.shape[:ax] + (max_batch,) + s.shape[ax + 1:], s.dtype),
            spec1, axes)
        # admission scatter: one dynamic_update_slice per leaf along its
        # batch axis; slot index is traced so one compilation serves every
        # slot
        self._insert_fn = jax.jit(
            lambda cache, new, slot: jax.tree.map(
                lambda c, n, ax: jax.lax.dynamic_update_slice_in_dim(
                    c, n.astype(c.dtype), slot, axis=ax),
                cache, new, self._axes))
        self.slots: list[Optional[_Slot]] = [None] * max_batch
        self.queue: deque[Request] = deque()
        self.results: dict[int, np.ndarray] = {}
        self.evicted: list[int] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.metrics.gauge("serve_queue_depth", lambda: len(self.queue))
        self.metrics.gauge("serve_slots_active", lambda: self.slots_active)

    # -- introspection -----------------------------------------------------
    @property
    def slots_active(self) -> int:
        return sum(1 for s in self.slots if s is not None)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    @property
    def outstanding(self) -> int:
        """Requests admitted or queued but not yet finished."""
        return self.queue_depth + self.slots_active

    # -- request intake ----------------------------------------------------
    def submit(self, requests: Request | Sequence[Request]):
        """Enqueue requests.  A request that cannot fit the sequence budget
        (``prefix + prompt + max_new_tokens > max_seq`` — its decode writes
        would run off the end of the cache) is EVICTED at admission control:
        its uid lands in ``self.evicted`` and the ``serve_evicted`` counter,
        never in the queue."""
        if isinstance(requests, Request):
            requests = [requests]
        for r in requests:
            if self._prefix + len(r.prompt) + r.max_new_tokens > self.max_seq:
                self.evicted.append(r.uid)
                self.metrics.inc("serve_evicted")
                continue
            self.queue.append(r)

    # -- admission ---------------------------------------------------------
    def prefill_request(self, req: Request) -> Admission:
        """Prefill one request into a fresh single-slot cache (pure w.r.t.
        the shared cache).  The prefill logits yield the first generated
        token, exactly like the static engine."""
        batch = {"tokens": jnp.asarray(req.prompt.astype(np.int32)[None]),
                 **modal_dummy_inputs(self.cfg, 1)}
        cache, logits = self._prefill(self.params, batch)
        self.metrics.inc("serve_prefill_tokens", len(req.prompt))
        return Admission(req=req, cache=cache,
                         first_tok=int(jnp.argmax(logits[0])))

    def free_slots(self) -> list[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    def insert(self, adm: Admission) -> Optional[int]:
        """Scatter an admission into a free slot (mutates the shared cache).
        Returns the slot index, or None when the request completed at
        admission (``max_new_tokens == 1``: the prefill logits were the
        whole generation, no slot needed)."""
        self.metrics.inc("serve_admitted")
        if adm.req.max_new_tokens <= 1:
            self._finish(adm.req, [adm.first_tok])
            return None
        free = self.free_slots()
        if not free:
            raise RuntimeError("insert() with no free slot")
        slot = free[0]
        self.cache = self._insert_fn(self.cache, adm.cache,
                                     jnp.int32(slot))
        self.slots[slot] = _Slot(
            req=adm.req,
            position=self._prefix + len(adm.req.prompt),
            next_tok=adm.first_tok, generated=[adm.first_tok])
        return slot

    def _admit_from_queue(self) -> int:
        """Admit queued requests into free slots (inline prefill+insert)."""
        n = 0
        while self.queue and (self.free_slots() or
                              self.queue[0].max_new_tokens <= 1):
            self.insert(self.prefill_request(self.queue.popleft()))
            n += 1
        return n

    # -- decode ------------------------------------------------------------
    def decode_round(self) -> list[Request]:
        """One decode step over ALL slots.  Active slots consume their last
        generated token at their own position; free slots decode a dummy
        token 0 at position 0 whose cache writes are dead (overwritten by
        the next admission's full-slot scatter).  Returns the requests that
        finished this round (their slots are already free)."""
        if self.slots_active == 0:
            return []
        toks = np.zeros((self.max_batch, 1), np.int32)
        pos = np.zeros((self.max_batch,), np.int32)
        for i, s in enumerate(self.slots):
            if s is not None:
                toks[i, 0] = s.next_tok
                pos[i] = s.position
        logits, self.cache = self._decode(
            self.params,
            {"tokens": jnp.asarray(toks), "positions": jnp.asarray(pos)},
            self.cache)
        nxt = np.asarray(jnp.argmax(logits, -1), np.int32)
        self.metrics.inc("serve_decode_steps")
        finished = []
        for i, s in enumerate(self.slots):
            if s is None:
                continue
            s.position += 1
            s.next_tok = int(nxt[i])
            s.generated.append(s.next_tok)
            if s.remaining == 0:
                self._finish(s.req, s.generated)
                self.slots[i] = None
                finished.append(s.req)
        return finished

    def decode_rounds(self, max_rounds: int) -> list[Request]:
        """Up to ``max_rounds`` decode steps, stopping early the moment any
        slot finishes — freed capacity should go back to admission, not to
        more rounds of a smaller batch.  The ServeDriver's decode-task
        payload."""
        for _ in range(max_rounds):
            finished = self.decode_round()
            if finished or self.slots_active == 0:
                return finished
        return []

    def _finish(self, req: Request, generated: list):
        self.results[req.uid] = np.asarray(
            generated[:req.max_new_tokens], np.int32)
        self.metrics.inc("serve_completed")

    # -- standalone loop ---------------------------------------------------
    def step(self) -> list[Request]:
        """One engine iteration: admit whatever fits, then one decode step.
        Admission happens BETWEEN decode steps — the continuous-batching
        invariant — so a request arriving mid-generation joins the running
        batch without draining it."""
        self._admit_from_queue()
        return self.decode_round()

    def run(self, requests: Sequence[Request]) -> dict:
        """Convenience: serve ``requests`` to completion; returns
        uid -> generated tokens (evicted uids excluded — see ``evicted``)."""
        self.submit(list(requests))
        while self.outstanding:
            self.step()
        return dict(self.results)
