"""Serving engines.  This module holds the STATIC-batch baseline
(``ServeEngine``: requests grouped by prompt length, one prefill + decode
loop per group — the whole batch drains before the next group starts) plus
the pieces it shares with the continuous-batching engine
(``repro.serve.continuous.ContinuousEngine``): the ``Request`` record, the
modal dummy-input builder, and the ``greedy_reference`` oracle.

Both engines are SPMD payloads like any other: the runtime can schedule
generation as tasks on private sub-meshes next to ETL and training tasks
(examples/serve_lm.py, ``repro.serve.driver.ServeDriver``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import registry
from repro.models.attention import AttnMode


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (prompt_len,) int32
    max_new_tokens: int = 16
    uid: int = 0


def modal_dummy_inputs(cfg: ModelConfig, batch_size: int) -> dict:
    """Zero-filled placeholder modal inputs for a ``batch_size`` batch: the
    vision/audio frontends are stubs per the assignment, so vlm prompts carry
    all-zero patch embeddings and audio prompts all-zero frame embeddings.
    Shared by both engines and the oracle so the placeholders can never
    drift apart between them."""
    extras = {}
    if cfg.family == "vlm":
        extras["prefix_embeds"] = jnp.zeros(
            (batch_size, cfg.n_patches, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.family == "audio":
        extras["frames"] = jnp.zeros(
            (batch_size, cfg.n_encoder_frames, cfg.d_model),
            jnp.dtype(cfg.dtype))
    return extras


def prompt_prefix_len(cfg: ModelConfig) -> int:
    """Positions a prompt's KV entries start AFTER: vlm patch embeddings are
    prepended to the token stream, so generation positions are offset by
    ``n_patches``; every other family starts at 0."""
    return cfg.n_patches if cfg.family == "vlm" else 0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.api = registry.get_model(cfg)
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._decode = jax.jit(
            lambda p, b, c: self.api.decode_step(p, cfg, b, c))
        self._prefill = jax.jit(
            lambda p, b: self.api.prefill(p, cfg, b, max_seq, AttnMode()))

    def run_requests(self, requests: Sequence[Request]):
        """Static-batch generation; returns dict uid -> generated tokens.
        Requests are grouped by prompt length (causal prefill over padding
        would corrupt the cache), then chunked to max_batch."""
        out = {}
        by_len: dict[int, list] = {}
        for r in requests:
            by_len.setdefault(len(r.prompt), []).append(r)
        for _, group in sorted(by_len.items()):
            for i in range(0, len(group), self.max_batch):
                out.update(self._run_batch(group[i:i + self.max_batch]))
        return out

    def _run_batch(self, requests):
        b = len(requests)
        plen = len(requests[0].prompt)
        toks = jnp.asarray(np.stack([r.prompt for r in requests]).astype(np.int32))
        batch = {"tokens": toks, **modal_dummy_inputs(self.cfg, b)}
        cache, logits = self._prefill(self.params, batch)

        prefix = prompt_prefix_len(self.cfg)
        positions = np.full((b,), prefix + plen, np.int32)
        max_new = max(r.max_new_tokens for r in requests)
        gen = np.zeros((b, max_new), np.int32)
        next_tok = np.asarray(jnp.argmax(logits, -1), np.int32)
        for t in range(max_new):
            gen[:, t] = next_tok
            db = {"tokens": jnp.asarray(next_tok[:, None]),
                  "positions": jnp.asarray(positions)}
            logits, cache = self._decode(self.params, db, cache)
            next_tok = np.asarray(jnp.argmax(logits, -1), np.int32)
            positions += 1
        return {r.uid: gen[i, :r.max_new_tokens] for i, r in enumerate(requests)}


def greedy_reference(cfg, params, prompt: np.ndarray, n_new: int):
    """Oracle: full forward re-run per generated token (tests)."""
    api = registry.get_model(cfg)
    toks = list(map(int, prompt))
    for _ in range(n_new):
        batch = {"tokens": jnp.asarray(np.asarray(toks, np.int32)[None]),
                 **modal_dummy_inputs(cfg, 1)}
        logits = api.forward(params, cfg, batch)
        toks.append(int(jnp.argmax(logits[0, -1])))
    return np.asarray(toks[len(prompt):], np.int32)
