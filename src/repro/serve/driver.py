"""ServeDriver: the serving loop as SCHEDULER TASKS on the pilot runtime.

``ContinuousEngine.run`` is a tight in-process loop; this driver breaks it
into the two phases a serving tier actually schedules differently and
submits each as its own :class:`~repro.core.task.TaskDescription` through a
:class:`~repro.core.scheduler.SchedulerSession`:

* **prefill tasks** (pipeline tag ``serve-prefill``) — compute the
  single-slot caches for a chunk of queued requests.  Pure with respect to
  the shared slot cache (``ContinuousEngine.prefill_request``), so a
  prefill task runs CONCURRENTLY with the decode task on whatever devices
  the scheduler gives it;
* **decode tasks** (pipeline tag ``serve-decode``) — run decode rounds over
  the live batch (``decode_rounds``), returning early the moment a slot
  frees so capacity goes back to admission.

Because the two phases carry different pipeline tags, the session's policy
machinery applies unchanged: under ``BATCH`` each phase gets its own private
static sub-mesh next to ETL pipelines (the paper's heterogeneous-task
coupling), under ``HETEROGENEOUS`` they share the pool with everything
else.  Admissions produced by a finished prefill task are scattered into
the shared cache by the driver thread, and only while no decode task is in
flight — the one serialization point the shared cache needs.

The driver is the telemetry source for the tier: every loop it snapshots
the engine's :class:`~repro.obs.MetricsRegistry` (queue depth, slot
occupancy, admitted/completed/evicted) into the session via
``SchedulerSession.record_telemetry`` — the same ``telemetry`` TraceEvent
stream worker heartbeats use, so the flight recorder and Perfetto export
pick the serve gauges up with zero new plumbing.  An optional
:class:`~repro.serve.autoscale.ServeAutoscaler` observes the same gauges
and drives ``add_worker`` / ``retire_worker`` (or ``inject_grow`` /
``inject_retire``) — backlog grows the pool, sustained idleness shrinks it.

The payloads close over the engine, so the driver requires an IN-PROCESS
executor (``ThreadExecutor``, or the virtual clock for shape tests) — on a
``ProcessExecutor`` the closures would be shipped by value and the shared
cache could not be mutated coherently.  The cross-process serving story is
one engine per worker behind a router, not one cache across workers.
"""
from __future__ import annotations

import itertools
from typing import Optional, Sequence

from repro.core.scheduler import SchedulerSession
from repro.core.task import TaskDescription, TaskState
from repro.serve.autoscale import ServeAutoscaler
from repro.serve.continuous import ContinuousEngine
from repro.serve.engine import Request

PREFILL_PIPELINE = "serve-prefill"
DECODE_PIPELINE = "serve-decode"


class ServeDriver:
    def __init__(self, engine: ContinuousEngine, session: SchedulerSession,
                 *, prefill_ranks: int = 1, decode_ranks: int = 1,
                 decode_chunk: int = 8, admit_chunk: Optional[int] = None,
                 autoscaler: Optional[ServeAutoscaler] = None,
                 telemetry_interval: float = 0.05):
        self.engine = engine
        self.session = session
        self.prefill_ranks = prefill_ranks
        self.decode_ranks = decode_ranks
        self.decode_chunk = decode_chunk
        self.admit_chunk = admit_chunk or engine.max_batch
        self.autoscaler = autoscaler
        self.telemetry_interval = telemetry_interval
        self._seq = itertools.count()
        self._parked: list = []          # admissions awaiting a free slot
        self._prefill_uid: Optional[int] = None
        self._decode_uid: Optional[int] = None
        self._last_telemetry = -float("inf")

    # -- task factories ----------------------------------------------------
    def _submit_prefill(self, reqs: Sequence[Request]):
        eng = self.engine

        def payload(comm, reqs=tuple(reqs)):
            return [eng.prefill_request(r) for r in reqs]

        # max_retries=0: prefill is pure, but a retry would double-count the
        # serve_prefill_tokens evidence; failures surface to the caller
        [t] = self.session.submit([TaskDescription(
            name=f"serve-prefill#{next(self._seq)}", ranks=self.prefill_ranks,
            fn=payload, max_retries=0, tags={"pipeline": PREFILL_PIPELINE})])
        self._prefill_uid = t.uid

    def _submit_decode(self):
        eng, n = self.engine, self.decode_chunk

        def payload(comm):
            return eng.decode_rounds(n)

        # max_retries=0: decode_rounds mutates the slot cache per round, so
        # a blind re-run would decode the same positions twice
        [t] = self.session.submit([TaskDescription(
            name=f"serve-decode#{next(self._seq)}", ranks=self.decode_ranks,
            fn=payload, max_retries=0, tags={"pipeline": DECODE_PIPELINE})])
        self._decode_uid = t.uid

    # -- telemetry / autoscale --------------------------------------------
    def _pulse(self):
        eng = self.engine
        now = self.session.executor.now()
        if self.autoscaler is not None:
            self.autoscaler.observe(eng.queue_depth + len(self._parked),
                                    eng.slots_active, eng.max_batch)
        if now - self._last_telemetry < self.telemetry_interval:
            return
        self._last_telemetry = now
        snap = eng.metrics.snapshot()
        snap["serve_slot_occupancy"] = eng.slots_active / eng.max_batch
        snap["serve_parked_admissions"] = len(self._parked)
        self.session.record_telemetry(snap, worker="serve-driver")

    # -- the loop ----------------------------------------------------------
    def run(self, requests: Sequence[Request],
            timeout: Optional[float] = None) -> dict:
        """Serve ``requests`` to completion through scheduler tasks; returns
        uid -> generated tokens (evicted uids excluded).  Raises on a failed
        serve task — there is no silent partial result."""
        eng = self.engine
        pre_evicted, pre_results = len(eng.evicted), len(eng.results)
        eng.submit(list(requests))
        expected = len(requests) - (len(eng.evicted) - pre_evicted)
        deadline = None if timeout is None \
            else self.session.executor.now() + timeout
        while len(eng.results) - pre_results < expected:
            if deadline is not None and \
                    self.session.executor.now() > deadline:
                raise TimeoutError(
                    f"serve driver: {len(eng.results)}/{expected} finished")
            # 1. insert parked admissions — only while no decode task can
            #    be touching the shared cache
            if self._decode_uid is None:
                while self._parked and (eng.free_slots()
                                        or self._parked[0].req
                                        .max_new_tokens <= 1):
                    eng.insert(self._parked.pop(0))
            # 2. keep one prefill task in flight while requests queue and
            #    admission capacity (free + soon-free slots) exists
            if self._prefill_uid is None and eng.queue and \
                    len(self._parked) < self.admit_chunk:
                take = min(len(eng.queue),
                           self.admit_chunk - len(self._parked))
                reqs = [eng.queue.popleft() for _ in range(take)]
                self._submit_prefill(reqs)
            # 3. keep one decode task in flight while slots are live
            if self._decode_uid is None and eng.slots_active:
                self._submit_decode()
            self._pulse()
            if self._prefill_uid is None and self._decode_uid is None:
                continue   # nothing in flight: admission made progress above
            for task in self.session.wait_any(timeout=1.0):
                if task.uid == self._prefill_uid:
                    self._prefill_uid = None
                    if task.state is not TaskState.DONE:
                        raise RuntimeError(
                            f"serve prefill task failed: {task.error}")
                    self._parked.extend(task.result)
                elif task.uid == self._decode_uid:
                    self._decode_uid = None
                    if task.state is not TaskState.DONE:
                        raise RuntimeError(
                            f"serve decode task failed: {task.error}")
        self._pulse()
        return dict(eng.results)
