"""Serve-tier autoscaling: sustained telemetry drives the elastic pilot.

The policy watches the two signals the serving loop already exports through
its :class:`~repro.obs.MetricsRegistry` — queue depth (demand the current
slots cannot absorb) and slot idleness (capacity nobody is using) — and
turns SUSTAINED pressure into the elastic-pool operations PR 5 added:
``ProcessExecutor.add_worker`` / ``retire_worker`` on the pilot,
``inject_grow`` / ``inject_retire`` on any in-process executor.  Transient
spikes are ignored by construction: a condition must hold continuously for
``sustain_s`` before an action fires, and actions are separated by
``cooldown_s`` so a grow gets to take effect before the next decision.

Thresholds come from the constructor or the ``REPRO_SERVE_*`` env knobs
(documented in docs/OPERATIONS.md):

* ``REPRO_SERVE_QUEUE_HIGH``  — queue depth above which the tier is
  considered backlogged (default 4);
* ``REPRO_SERVE_IDLE_FRAC``   — active-slot fraction below which (with an
  empty queue) the tier is considered idle (default 0.25);
* ``REPRO_SERVE_SUSTAIN_S``   — how long a condition must hold (default 2.0);
* ``REPRO_SERVE_COOLDOWN_S``  — minimum gap between actions (default 5.0).

The policy is deliberately executor-agnostic: it calls ``grow()`` /
``retire()`` callables and counts workers itself, so the same object is unit
testable with a fake clock and drives a real pilot unchanged.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable, Optional


def _env_float(name: str, default: float) -> float:
    try:
        return float(os.environ.get(name, default))
    except ValueError:
        return default


@dataclasses.dataclass
class AutoscaleConfig:
    queue_high: int = 4
    idle_frac: float = 0.25
    sustain_s: float = 2.0
    cooldown_s: float = 5.0
    min_workers: int = 1
    max_workers: int = 4

    @classmethod
    def from_env(cls, **overrides) -> "AutoscaleConfig":
        kw = dict(
            queue_high=int(_env_float("REPRO_SERVE_QUEUE_HIGH", 4)),
            idle_frac=_env_float("REPRO_SERVE_IDLE_FRAC", 0.25),
            sustain_s=_env_float("REPRO_SERVE_SUSTAIN_S", 2.0),
            cooldown_s=_env_float("REPRO_SERVE_COOLDOWN_S", 5.0))
        kw.update(overrides)
        return cls(**kw)


class ServeAutoscaler:
    """Sustained-pressure hysteresis over (queue depth, slot idleness).

    ``observe`` is called with the current gauges; it returns ``"grow"`` /
    ``"retire"`` when it fired (after invoking the callback) or None.  The
    grow condition is a backlog (`queue_depth > queue_high`) sustained for
    ``sustain_s``; the retire condition is an EMPTY queue with at most
    ``idle_frac * max_slots`` slots active, sustained the same way.  A
    failing callback (e.g. ``add_worker`` on a pool already at its host's
    capacity) is swallowed: autoscaling is advisory, serving must not die
    because scaling did.
    """

    def __init__(self, grow: Callable[[], object],
                 retire: Callable[[], object],
                 config: Optional[AutoscaleConfig] = None,
                 workers: int = 1,
                 clock: Callable[[], float] = time.monotonic):
        self.cfg = config or AutoscaleConfig.from_env()
        self._grow = grow
        self._retire = retire
        self.workers = workers
        self._clock = clock
        self._since: Optional[float] = None    # condition onset time
        self._cond: Optional[str] = None       # which condition is running
        self._last_action: float = -float("inf")
        self.actions: list[tuple[float, str]] = []

    def observe(self, queue_depth: int, slots_active: int,
                max_slots: int) -> Optional[str]:
        now = self._clock()
        if queue_depth > self.cfg.queue_high:
            cond = "grow"
        elif queue_depth == 0 and \
                slots_active <= self.cfg.idle_frac * max_slots:
            cond = "retire"
        else:
            cond = None
        if cond != self._cond:
            self._cond, self._since = cond, now
        if cond is None or now - self._since < self.cfg.sustain_s:
            return None
        if now - self._last_action < self.cfg.cooldown_s:
            return None
        if cond == "grow" and self.workers >= self.cfg.max_workers:
            return None
        if cond == "retire" and self.workers <= self.cfg.min_workers:
            return None
        try:
            (self._grow if cond == "grow" else self._retire)()
        except Exception:  # noqa: BLE001 — advisory: serving outlives scaling
            return None
        self.workers += 1 if cond == "grow" else -1
        self._last_action = now
        self._since = now   # re-arm: the condition must sustain again
        self.actions.append((now, cond))
        return cond
