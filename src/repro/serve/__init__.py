"""Serving tier: static-batch baseline, continuous-batching engine, the
scheduler-task driver, and the telemetry-driven autoscaler."""
from repro.serve.autoscale import AutoscaleConfig, ServeAutoscaler
from repro.serve.continuous import Admission, ContinuousEngine, cache_batch_axes
from repro.serve.driver import ServeDriver
from repro.serve.engine import (Request, ServeEngine, greedy_reference,
                                modal_dummy_inputs, prompt_prefix_len)

__all__ = [
    "Admission", "AutoscaleConfig", "ContinuousEngine", "Request",
    "ServeAutoscaler", "ServeDriver", "ServeEngine", "cache_batch_axes",
    "greedy_reference", "modal_dummy_inputs", "prompt_prefix_len",
]
