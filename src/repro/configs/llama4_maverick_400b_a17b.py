"""llama4-maverick-400b-a17b [moe]: interleaved MoE (every 2nd layer),
128 routed experts top-1 + 1 shared expert; dense layers d_ff=16384.
~400B total / ~17B active. [hf:meta-llama/Llama-4-*; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab_size=202048, head_dim=128, rope_theta=5e5,
    n_experts=128, n_shared_experts=1, top_k=1,
    moe_layer_period=2, d_ff_dense=16384,
)
