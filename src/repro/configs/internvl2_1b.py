"""internvl2-1b [vlm]: InternViT frontend STUB (patch embeddings provided by
input_specs) + qwen2-0.5b-style LM backbone. [arXiv:2404.16821; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151655, head_dim=64, rope_theta=1e6,
    n_patches=256, tie_embeddings=True,
)
