"""whisper-medium [audio]: enc-dec; conv/log-mel frontend STUB (input_specs
provides frame embeddings (B, 1500, d)). 24 enc + 24 dec layers.
[arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865, head_dim=64, rope_theta=1e4,
    n_encoder_layers=24, n_encoder_frames=1500, tie_embeddings=True,
)
