"""Config dataclasses for models, input shapes and parallelism.

Every assigned architecture gets one module in this package exporting CONFIG
(a ModelConfig with the exact published dimensions). ``reduced()`` derives a
tiny same-family config for CPU smoke tests; the full configs are exercised
only via the AOT dry-run (ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default: d_model // n_heads
    qk_norm: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_layer_period: int = 1               # every k-th layer is MoE (llama4: 2)
    d_ff_dense: Optional[int] = None        # d_ff of non-MoE layers (llama4: 16384)
    capacity_factor: float = 1.25

    # --- SSM (mamba) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_version: int = 1                    # 1 = mamba1 (falcon-mamba), 2 = mamba2
    ssm_head_dim: int = 64                  # mamba2 head dim

    # --- hybrid (zamba2): one *shared* attn+MLP block applied every k SSM blocks ---
    shared_attn_period: int = 0

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0
    n_encoder_frames: int = 0               # stubbed frontend sequence length

    # --- VLM (internvl2) ---
    n_patches: int = 0                      # stubbed patch embeddings prepended

    # numerics
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    attn_chunk: int = 0                     # 0 = full attention; >0 = chunked flash-style
    ssm_chunk: int = 128                    # seq chunk for the selective-scan train path
    unroll_scans: bool = False              # analysis mode: fully unroll all scans so
                                            # cost_analysis counts every iteration
    # --- perf knobs (see EXPERIMENTS.md §Perf) ---
    fused_ssm_y: bool = False               # fuse the C-contraction into the chunk
                                            # scan: never materialize (S, d_inner, N)
    causal_skip: bool = False               # skip fully-masked causal attn blocks
    remat_mode: str = "dots"                # dots | nothing | none
    ssm_scan_dtype: str = "float32"         # bfloat16 halves the scan's HBM
                                            # traffic (TPU kernel keeps f32 acc)

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0 or self.n_kv_heads == 0

    # ---------- derived quantities ----------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def dt_rank(self) -> int:
        return math.ceil(self.d_model / 16)

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        # interleaved: layers (period-1, 2*period-1, ...) are MoE when period>1;
        # period == 1 means every layer.
        return (i % self.moe_layer_period) == (self.moe_layer_period - 1)

    @property
    def n_moe_layers(self) -> int:
        return sum(self.is_moe_layer(i) for i in range(self.n_layers))

    # ---------- parameter counting (exact, mirrors models/*.py init) ----------
    def param_count(self) -> int:
        d, hd = self.d_model, self.head_dim
        nh, nkv = self.n_heads, self.n_kv_heads

        def attn_params(dm, heads, kv, hdim, with_qk_norm):
            p = dm * heads * hdim + 2 * dm * kv * hdim + heads * hdim * dm
            if with_qk_norm:
                p += 2 * hdim
            return p

        def mlp_params(dm, ff):
            return 3 * dm * ff  # gate, up, down (SwiGLU)

        total = self.vocab_size * d  # embedding
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        total += d  # final norm

        if self.family in ("dense", "moe", "vlm"):
            for i in range(self.n_layers):
                total += 2 * d  # pre-norms
                total += attn_params(d, nh, nkv, hd, self.qk_norm)
                if self.is_moe_layer(i):
                    total += d * self.n_experts            # router
                    total += self.n_experts * mlp_params(d, self.d_ff)
                    total += self.n_shared_experts * mlp_params(d, self.d_ff)
                    if self.n_shared_experts:
                        total += d * 1                      # shared gate
                else:
                    total += mlp_params(d, self.d_ff_dense or self.d_ff)
        elif self.family == "ssm":
            for _ in range(self.n_layers):
                total += d  # pre-norm
                total += self._mamba1_params()
        elif self.family == "hybrid":
            for _ in range(self.n_layers):
                total += d
                total += self._mamba2_params()
            # one shared transformer block (single copy)
            total += 2 * d + attn_params(d, nh, nkv, hd, False) + mlp_params(d, self.d_ff)
        elif self.family == "audio":
            # encoder layers (self-attn, MHA) + decoder layers (self + cross)
            for _ in range(self.n_encoder_layers):
                total += 2 * d + attn_params(d, nh, nh, hd, False) + mlp_params(d, self.d_ff)
            for _ in range(self.n_layers):
                total += 3 * d  # pre-norms (self, cross, mlp)
                total += attn_params(d, nh, nkv, hd, False)       # self
                total += attn_params(d, nh, nh, hd, False)        # cross
                total += mlp_params(d, self.d_ff)
            total += d  # encoder final norm
        else:
            raise ValueError(self.family)
        return total

    def _mamba1_params(self) -> int:
        d, di, st, dtr = self.d_model, self.d_inner, self.ssm_state, self.dt_rank
        p = d * 2 * di                        # in_proj (x, z)
        p += self.ssm_conv * di + di          # depthwise conv + bias
        p += di * (dtr + 2 * st)              # x_proj -> dt, B, C
        p += dtr * di + di                    # dt_proj
        p += di * st                          # A_log
        p += di                               # D
        p += di * d                           # out_proj
        return p

    def _mamba2_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        # in_proj -> z, x, B, C, dt  (grouped B/C: one group)
        p = d * (2 * di + 2 * st + nh)
        p += self.ssm_conv * (di + 2 * st) + (di + 2 * st)   # conv over x,B,C
        p += nh + nh + nh                     # A_log, D, dt_bias (per head)
        p += di                               # gated rmsnorm weight
        p += di * d                           # out_proj
        return p

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        total = self.param_count()
        per_expert = 3 * self.d_model * self.d_ff
        inactive = self.n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return total - inactive


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Sub-quadratic state: only SSM/hybrid archs run the 500k-decode shape.
LONG_CONTEXT_FAMILIES = ("ssm", "hybrid")


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    if shape.name == "long_500k":
        return cfg.family in LONG_CONTEXT_FAMILIES
    return True


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How a step is sharded onto the mesh (see distributed/sharding.py)."""
    fsdp: bool = True            # shard params/opt-state over 'data'
    tensor_parallel: bool = True # shard heads/ff/experts over 'model'
    sequence_parallel: bool = False  # shard long-prefill activations over 'model'
    pipeline_stages: int = 1     # >1: pod axis becomes a pipeline axis
    grad_compression: str = "none"  # none | int8
    remat_policy: str = "minimal"   # none | minimal | full
    microbatches: int = 1
    attn_block: int = 512           # q/kv tile for blockwise attention
    moe_impl: str = "gspmd"         # gspmd | shardmap (local-expert EP)
    dp_axes: tuple = ("pod", "data")  # axes used for data parallelism (present subset)
    fsdp_axes: tuple = ("data",)      # axes params/opt-state shard over (ZeRO-3)


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests. Preserves structure
    (GQA grouping, MoE routing, hybrid period, enc-dec) at toy sizes."""
    nh = 4
    nkv = max(1, min(cfg.n_kv_heads, 2)) if cfg.n_kv_heads else 0
    if cfg.n_kv_heads == cfg.n_heads:  # MHA archs stay MHA
        nkv = nh
    kw = dict(
        name=cfg.name + "-reduced",
        family=cfg.family,
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 4),
        d_model=64,
        n_heads=nh,
        n_kv_heads=nkv,
        d_ff=128,
        vocab_size=512,
        head_dim=16,
        qk_norm=cfg.qk_norm,
        rope_theta=cfg.rope_theta,
        tie_embeddings=cfg.tie_embeddings,
        ssm_state=min(cfg.ssm_state, 8) if cfg.ssm_state else 0,
        ssm_conv=cfg.ssm_conv,
        ssm_expand=cfg.ssm_expand,
        ssm_version=cfg.ssm_version,
        ssm_head_dim=16,
        ssm_chunk=8,
        shared_attn_period=2 if cfg.shared_attn_period else 0,
        n_encoder_layers=2 if cfg.n_encoder_layers else 0,
        n_encoder_frames=16 if cfg.n_encoder_frames else 0,
        n_patches=8 if cfg.n_patches else 0,
        dtype="float32",
        remat=False,
        scan_layers=cfg.scan_layers,
    )
    if cfg.n_experts:
        kw.update(
            n_experts=4,
            n_shared_experts=min(cfg.n_shared_experts, 1),
            top_k=min(cfg.top_k, 2),
            moe_layer_period=cfg.moe_layer_period,
            capacity_factor=4.0,
            d_ff_dense=128 if cfg.d_ff_dense else None,
        )
    if cfg.family == "hybrid":
        kw["n_layers"] = 4  # 2 groups x 2 layers with period 2
    return ModelConfig(**kw)
