"""Architecture registry: ``get_config(arch_id)`` / ``list_archs()``.

Arch ids follow the assignment sheet; module names are the sanitized forms.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    ParallelConfig,
    ShapeConfig,
    reduced,
    supports_shape,
)

_ARCH_MODULES = {
    "zamba2-7b": "zamba2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "internvl2-1b": "internvl2_1b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "qwen2-moe-a2.7b": "qwen2_moe_a27b",
    "qwen3-8b": "qwen3_8b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "granite-3-8b": "granite_3_8b",
    "minitron-8b": "minitron_8b",
    "whisper-medium": "whisper_medium",
}


def list_archs() -> list[str]:
    return list(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


__all__ = [
    "ModelConfig", "ParallelConfig", "ShapeConfig", "SHAPES",
    "get_config", "get_shape", "list_archs", "reduced", "supports_shape",
]
