"""qwen2-moe-a2.7b [moe]: 60 routed experts top-4 + 4 shared experts
(d_ff 1408 each; released shared-intermediate 5632 = 4x1408), all layers MoE.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151936, head_dim=128, rope_theta=1e6,
    n_experts=60, n_shared_experts=4, top_k=4, moe_layer_period=1,
)
