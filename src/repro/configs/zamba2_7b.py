"""zamba2-7b [hybrid]: 81 Mamba2 blocks + one shared attention/MLP block
applied every 9 blocks (single weight copy). [arXiv:2411.15242; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
    vocab_size=32000, head_dim=112,
    ssm_state=64, ssm_version=2, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    shared_attn_period=9, rope_theta=1e4, ssm_chunk=1024,
)
