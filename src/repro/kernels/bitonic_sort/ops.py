"""jit'd wrapper: pads to a power of two with max-sentinels, sorts, trims."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.bitonic_sort.bitonic_sort import bitonic_sort_kernel


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@partial(jax.jit, static_argnames=("interpret",))
def bitonic_sort(keys, payload=None, *, interpret: bool = False):
    """keys (rows, n) any float/int; optional payload (rows, n) int32.
    Returns (sorted_keys, payload_perm) trimmed to the input width."""
    rows, n = keys.shape
    m = _next_pow2(n)
    if payload is None:
        payload = jnp.broadcast_to(jnp.arange(n, dtype=jnp.int32), (rows, n))
    if m != n:
        if jnp.issubdtype(keys.dtype, jnp.integer):
            sent = jnp.iinfo(keys.dtype).max
        else:
            sent = jnp.finfo(keys.dtype).max
        keys = jnp.pad(keys, ((0, 0), (0, m - n)), constant_values=sent)
        payload = jnp.pad(payload, ((0, 0), (0, m - n)), constant_values=-1)
    ks, ps = bitonic_sort_kernel(keys, payload, interpret=interpret)
    return ks[:, :n], ps[:, :n]
