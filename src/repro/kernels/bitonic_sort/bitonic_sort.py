"""Bitonic sort Pallas TPU kernel — the local-sort hot-spot of distributed
sample-sort.

Sorting networks are the TPU-idiomatic sort: fixed data-independent
compare-exchange stages that vectorize over the VPU lanes, no data-dependent
control flow.  Keys (+ a payload permutation) for one block live entirely in
VMEM; the O(log^2 n) stages are statically unrolled.

Grid: (rows,) — each grid cell sorts one independent row of a (rows, n)
batch (n must be a power of two; ops.py pads with +inf sentinels).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _compare_exchange(keys, payload, partner_xor: int, direction_bit: int, n: int):
    idx = jax.lax.iota(jnp.int32, n)
    partner = idx ^ partner_xor
    pk = keys[partner]
    pp = payload[partner]
    is_low = idx < partner
    ascending = (idx & direction_bit) == 0
    keep_self = jnp.where(is_low,
                          jnp.where(ascending, keys <= pk, keys >= pk),
                          jnp.where(ascending, keys >= pk, keys <= pk))
    new_keys = jnp.where(keep_self, keys, pk)
    new_payload = jnp.where(keep_self, payload, pp)
    return new_keys, new_payload


def _kernel(k_ref, p_ref, ko_ref, po_ref, *, n: int):
    keys = k_ref[0]
    payload = p_ref[0]
    size = 2
    while size <= n:
        stride = size // 2
        while stride >= 1:
            keys, payload = _compare_exchange(keys, payload, stride, size, n)
            stride //= 2
        size *= 2
    ko_ref[0] = keys
    po_ref[0] = payload


def bitonic_sort_kernel(keys, payload, *, interpret: bool = False):
    """keys (rows, n) with n a power of two; payload (rows, n) int32.
    Returns (sorted_keys, permuted_payload), ascending per row."""
    rows, n = keys.shape
    assert n & (n - 1) == 0, "n must be a power of two"
    kernel = functools.partial(_kernel, n=n)
    return pl.pallas_call(
        kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, n), lambda r: (r, 0)),
                  pl.BlockSpec((1, n), lambda r: (r, 0))],
        out_specs=[pl.BlockSpec((1, n), lambda r: (r, 0)),
                   pl.BlockSpec((1, n), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows, n), keys.dtype),
                   jax.ShapeDtypeStruct((rows, n), payload.dtype)],
        interpret=interpret,
    )(keys, payload)
