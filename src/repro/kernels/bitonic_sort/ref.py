"""Oracle: jnp.sort / argsort-gather."""
from __future__ import annotations

import jax.numpy as jnp


def sort_ref(keys, payload):
    order = jnp.argsort(keys, axis=-1, stable=True)
    return jnp.take_along_axis(keys, order, -1), \
        jnp.take_along_axis(payload, order, -1)
