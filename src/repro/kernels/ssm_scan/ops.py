"""jit'd wrapper for the selective-scan kernel."""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_kernel


@partial(jax.jit, static_argnames=("d_block", "chunk", "interpret"))
def ssm_scan(dt, A, Bm, Cm, x, *, d_block: int = 256, chunk: int = 64,
             interpret: bool = False):
    return ssm_scan_kernel(dt, A, Bm, Cm, x, d_block=d_block, chunk=chunk,
                           interpret=interpret)
