"""Selective-scan (Mamba1 core) Pallas TPU kernel.

Computes, for a diagonal SSM:   h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t * x_t
                                y_t = <h_t, C_t>
with the carried state h (d_block, N) resident in VMEM scratch across the
sequential seq-chunk grid dimension — the (S, D, N) expansion never touches
HBM, which is the whole point versus the chunked pure-jnp path in
models/ssm.py.

Grid: (batch, d_blocks, s_chunks); the innermost chunk axis iterates
sequentially per core, so the scratch carry is valid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, a_ref, bm_ref, cm_ref, x_ref, y_ref, h_scr, *,
            chunk: int):
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    dt = dt_ref[0].astype(jnp.float32)       # (chunk, dblk)
    a = a_ref[...].astype(jnp.float32)       # (dblk, N)
    bm = bm_ref[0].astype(jnp.float32)       # (chunk, N)
    cm = cm_ref[0].astype(jnp.float32)       # (chunk, N)
    x = x_ref[0].astype(jnp.float32)         # (chunk, dblk)

    def step(t, carry):
        h = carry                             # (dblk, N)
        decay = jnp.exp(dt[t][:, None] * a)   # (dblk, N)
        h = decay * h + (dt[t] * x[t])[:, None] * bm[t][None, :]
        y_t = jnp.sum(h * cm[t][None, :], axis=1)      # (dblk,)
        y_ref[0, pl.dslice(t, 1), :] = y_t[None, :].astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def ssm_scan_kernel(dt, A, Bm, Cm, x, *, d_block: int = 256, chunk: int = 64,
                    interpret: bool = False):
    """dt, x: (B, S, D); A: (D, N); Bm, Cm: (B, S, N).  Returns y (B, S, D)
    (f32) — caller adds the D*x skip term and gating."""
    b, s, d = dt.shape
    n = A.shape[1]
    d_block = min(d_block, d)
    chunk = min(chunk, s)
    assert d % d_block == 0 and s % chunk == 0
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, d // d_block, s // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, d_block), lambda b_, i, c: (b_, c, i)),
            pl.BlockSpec((d_block, n), lambda b_, i, c: (i, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, i, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b_, i, c: (b_, c, 0)),
            pl.BlockSpec((1, chunk, d_block), lambda b_, i, c: (b_, c, i)),
        ],
        out_specs=pl.BlockSpec((1, chunk, d_block), lambda b_, i, c: (b_, c, i)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((d_block, n), jnp.float32)],
        interpret=interpret,
    )(dt, A, Bm, Cm, x)
