"""Pure-jnp oracle: sequential lax.scan over time."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(dt, A, Bm, Cm, x):
    """dt,x (B,S,D); A (D,N); Bm,Cm (B,S,N) -> y (B,S,D) f32."""
    dt = dt.astype(jnp.float32)
    x = x.astype(jnp.float32)
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)
    A = A.astype(jnp.float32)
    b, s, d = dt.shape
    n = A.shape[1]

    def step(h, inp):
        dt_t, b_t, c_t, x_t = inp
        decay = jnp.exp(dt_t[..., None] * A)           # (B,D,N)
        h = decay * h + (dt_t * x_t)[..., None] * b_t[:, None, :]
        y = jnp.sum(h * c_t[:, None, :], axis=-1)      # (B,D)
        return h, y

    h0 = jnp.zeros((b, d, n), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (jnp.moveaxis(dt, 1, 0), jnp.moveaxis(Bm, 1, 0),
                          jnp.moveaxis(Cm, 1, 0), jnp.moveaxis(x, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)
