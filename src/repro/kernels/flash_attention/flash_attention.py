"""Flash attention Pallas TPU kernel: online-softmax tiling with the
(m, l, acc) running state in VMEM scratch across the sequential kv-block grid
dimension.  GQA is handled in the BlockSpec index maps (kv head = h // group),
so grouped K/V are never materialized per query head.

Grid: (batch, q_heads, q_blocks, kv_blocks) — the last dimension iterates
sequentially per TPU core, which is what makes the scratch carry valid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, causal: bool, q_block: int, kv_block: int,
            kv_blocks: int):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                  # (qb, hd)
    k = k_ref[0, 0]                                  # (kb, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        rows = i * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        cols = j * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    v = v_ref[0, 0]                                  # (kb, hd)
    pv = jax.lax.dot_general(p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + pv
    m_scr[...] = m_new

    @pl.when(j == kv_blocks - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, causal: bool = True, q_block: int = 128,
                           kv_block: int = 128, interpret: bool = False):
    """q (B, H, Sq, hd); k/v (B, K, Sk, hd) with H = K * group.
    Returns (B, H, Sq, hd) in q.dtype."""
    b, h, sq, hd = q.shape
    _, kh, sk, _ = k.shape
    group = h // kh
    assert sq % q_block == 0 and sk % kv_block == 0
    tq, tk = sq // q_block, sk // kv_block
    scale = hd ** -0.5

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               q_block=q_block, kv_block=kv_block, kv_blocks=tk)
    return pl.pallas_call(
        kernel,
        grid=(b, h, tq, tk),
        in_specs=[
            pl.BlockSpec((1, 1, q_block, hd), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
            pl.BlockSpec((1, 1, kv_block, hd),
                         lambda b_, h_, i, j: (b_, h_ // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_block, hd),
                               lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
