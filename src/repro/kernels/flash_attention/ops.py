"""jit'd public wrapper: (B,S,H,hd) layout + padding + GQA plumbing."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


@partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = False):
    """Model-layout entry point: q (B,Sq,H,hd), k/v (B,Sk,K,hd).
    Pads sequence lengths up to tile multiples (padded keys are masked by the
    causal structure / a validity clamp) and restores the layout."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    pad_q = (-sq) % q_block
    pad_k = (-sk) % kv_block
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        # pad keys with a sentinel that loses the softmax: zeros are fine for
        # causal (out of range); for non-causal we mask via huge negative dot —
        # achieved by padding K with zeros and relying on explicit masking in
        # the kernel only for causal. Non-causal callers must pass aligned Sk.
        assert causal, "non-causal flash requires kv_block-aligned Sk"
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    out = flash_attention_kernel(qt, kt, vt, causal=causal, q_block=q_block,
                                 kv_block=kv_block, interpret=interpret)
    if pad_q:
        out = out[:, :, :sq]
    return jnp.moveaxis(out, 1, 2)
