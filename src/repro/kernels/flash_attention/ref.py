"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q (B,H,Sq,hd); k/v (B,K,Sk,hd), H = K*group. Naive softmax attention."""
    b, h, sq, hd = q.shape
    kh = k.shape[1]
    g = h // kh
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * (hd ** -0.5)
    if causal:
        sk = k.shape[2]
        rows = jnp.arange(sq)[:, None] + (sk - sq)
        s = jnp.where(jnp.arange(sk)[None, :] <= rows, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w.astype(v.dtype), v).astype(q.dtype)
