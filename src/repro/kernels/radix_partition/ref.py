"""Oracle: stable within-bucket positions + histogram via jnp."""
from __future__ import annotations

import jax.numpy as jnp


def radix_partition_ref(buckets, n_buckets: int):
    onehot = buckets[:, None] == jnp.arange(n_buckets)[None, :]
    pos = (jnp.cumsum(onehot, axis=0) - onehot)
    within = jnp.sum(pos * onehot, axis=1).astype(jnp.int32)
    hist = jnp.sum(onehot, axis=0).astype(jnp.int32)
    return within, hist


def destinations_ref(buckets, n_buckets: int):
    within, hist = radix_partition_ref(buckets, n_buckets)
    offsets = jnp.cumsum(hist) - hist
    return offsets[buckets] + within, hist
