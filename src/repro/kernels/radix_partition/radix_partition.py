"""Radix/hash partition Pallas TPU kernel — the shuffle hot-spot.

TPU adaptation of CUDA atomic-histogram binning: the per-block histogram is a
ONE-HOT MATMUL (block_rows x n_buckets one-hot  @  ones) that runs on the MXU,
and the stable intra-bucket positions come from an exclusive cumsum over the
one-hot matrix.  Running bucket cursors persist in VMEM scratch across the
sequential block grid, yielding a globally stable partition in one pass.

Outputs: dest (n,) — destination slot of each row in bucket-major order —
and the final histogram (n_buckets,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(bucket_ref, dest_ref, hist_ref, cursor_scr, *, n_buckets: int,
            block: int, n_blocks: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        cursor_scr[...] = jnp.zeros_like(cursor_scr)

    b = bucket_ref[0]                                        # (block,)
    onehot = (b[:, None] ==
              jax.lax.iota(jnp.int32, n_buckets)[None, :]).astype(jnp.float32)
    # stable rank of each row within its bucket, inside this block
    ranks_f = jnp.cumsum(onehot, axis=0) - onehot            # exclusive cumsum
    rank = jnp.sum(ranks_f * onehot, axis=1).astype(jnp.int32)
    # block histogram via MXU matmul: (1, block) @ (block, n_buckets)
    ones = jnp.ones((1, block), jnp.float32)
    hist = jax.lax.dot_general(ones, onehot, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)[0]
    cursors = cursor_scr[...]
    dest_ref[0] = cursors[b].astype(jnp.int32) + rank
    cursor_scr[...] = cursors + hist.astype(jnp.int32)

    @pl.when(i == n_blocks - 1)
    def _emit():
        hist_ref[...] = cursor_scr[...]


def radix_partition_kernel(buckets, n_buckets: int, *, block: int = 1024,
                           interpret: bool = False):
    """buckets (n,) int32 in [0, n_buckets) -> (within_bucket_pos (n,),
    histogram (n_buckets,)).  Caller turns (bucket, pos, hist-prefix) into
    final destinations; see ops.py."""
    n = buckets.shape[0]
    block = min(block, n)
    assert n % block == 0
    kernel = functools.partial(_kernel, n_buckets=n_buckets, block=block,
                               n_blocks=n // block)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((1, block), lambda i: (0, i))],
        out_specs=[pl.BlockSpec((1, block), lambda i: (0, i)),
                   pl.BlockSpec((n_buckets,), lambda i: (0,))],
        out_shape=[jax.ShapeDtypeStruct((1, n), jnp.int32),
                   jax.ShapeDtypeStruct((n_buckets,), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((n_buckets,), jnp.int32)],
        interpret=interpret,
    )(buckets.reshape(1, n))
