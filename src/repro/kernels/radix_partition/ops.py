"""jit'd wrapper: bucket-major stable destinations for a partition/shuffle."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.radix_partition.radix_partition import radix_partition_kernel


@partial(jax.jit, static_argnames=("n_buckets", "block", "interpret"))
def radix_partition(buckets, n_buckets: int, *, block: int = 1024,
                    interpret: bool = False):
    """buckets (n,) int32 -> (dest (n,), hist (n_buckets,)):
    row i belongs at global position dest[i] of the bucket-major layout."""
    n = buckets.shape[0]
    if n_buckets == 1:
        # degenerate single-bucket partition: the identity.  Short-circuit
        # instead of launching the kernel — the (1,)-shaped hist output and
        # VMEM scratch are below TPU lane tiling, and the pad-correction
        # below would subtract the padded tail from the SAME bucket the real
        # rows occupy (padding targets bucket n_buckets - 1, which here is
        # also every real row's bucket).
        return jnp.arange(n, dtype=jnp.int32), jnp.full((1,), n, jnp.int32)
    pad = (-n) % block if n >= block else block - n
    b = jnp.pad(buckets, (0, pad), constant_values=n_buckets - 1) if pad else buckets
    within2d, hist = radix_partition_kernel(b, n_buckets, block=block,
                                            interpret=interpret)
    within = within2d[0, :n]
    if pad:
        hist = hist - jnp.bincount(b[n:], length=n_buckets).astype(jnp.int32)
    offsets = jnp.cumsum(hist) - hist
    return offsets[buckets] + within, hist
