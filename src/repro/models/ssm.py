"""State-space blocks: Mamba1 (falcon-mamba) and Mamba2 (zamba2).

Train/prefill path: chunked associative scan (seq chunks of ``cfg.ssm_chunk``)
so the (S, d_inner, d_state) tensor is never fully materialized — the pure-jnp
analogue of the kernels/ssm_scan Pallas kernel (which keeps the carried state
in VMEM scratch).  Decode path: O(1) recurrent step with (conv_state, h) carried
in the "cache".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_tokens
from repro.models.layers import dense_init, rms_norm


def _softplus(x):
    return jax.nn.softplus(x)


def _causal_conv(x, w, b):
    """Depthwise causal conv. x (B,S,C), w (K,C), b (C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        xp, w[:, None, :],  # (K, 1, C) — depthwise via feature_group_count
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=x.shape[-1])
    return out + b


def _conv_step(conv_state, x_new, w, b):
    """conv_state (B,K-1,C), x_new (B,C) -> (y (B,C), new_state)."""
    window = jnp.concatenate([conv_state, x_new[:, None, :]], axis=1)  # (B,K,C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b
    return y, window[:, 1:, :]


def _assoc_scan_fused(a, b, h0, cm, chunk: int, contract, unroll=1):
    """Like _assoc_scan_chunked but contracts each chunk's states with C
    immediately (``contract(h_chunk, c_chunk) -> y_chunk``), so the
    (S, ..., N) state history never exists outside one chunk — the pure-jnp
    analogue of the ssm_scan Pallas kernel's VMEM-resident state
    (perf knob ``cfg.fused_ssm_y``; see EXPERIMENTS.md §Perf)."""
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk
    ar = a.reshape((B, nc, chunk) + a.shape[2:])
    br = b.reshape((B, nc, chunk) + b.shape[2:])
    cr = cm.reshape((B, nc, chunk) + cm.shape[2:])

    def combine(left, right):
        al, bl = left
        ar_, br_ = right
        return ar_ * al, ar_ * bl + br_

    def chunk_body(h, abc):
        ac, bc, cc = abc
        pa, pb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = pb + pa * h[:, None]
        return h_all[:, -1], contract(h_all, cc)

    h_final, ys = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(ar, 1, 0), jnp.moveaxis(br, 1, 0),
         jnp.moveaxis(cr, 1, 0)), unroll=unroll)
    ys = jnp.moveaxis(ys, 0, 1).reshape((B, S) + ys.shape[3:])
    return ys, h_final


def _assoc_scan_chunked(a, b, h0, chunk: int, unroll=1):
    """h_t = a_t * h_{t-1} + b_t over axis=1, chunked.

    a, b: (B, S, ...) f32;  h0: (B, ...) f32.  Returns (h_all (B,S,...), h_final).
    """
    B, S = a.shape[0], a.shape[1]
    chunk = min(chunk, S)
    while S % chunk:          # largest divisor of S <= requested chunk
        chunk -= 1
    nc = S // chunk
    ar = a.reshape((B, nc, chunk) + a.shape[2:])
    br = b.reshape((B, nc, chunk) + b.shape[2:])

    def combine(left, right):
        al, bl = left
        ar_, br_ = right
        return ar_ * al, ar_ * bl + br_

    def chunk_body(h, ab):
        ac, bc = ab  # (B, chunk, ...)
        pa, pb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = pb + pa * h[:, None]
        return h_all[:, -1], h_all

    h_final, hs = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(ar, 1, 0), jnp.moveaxis(br, 1, 0)), unroll=unroll)
    hs = jnp.moveaxis(hs, 0, 1).reshape((B, S) + a.shape[2:])
    return hs, h_final


# ============================================================================
# Mamba1 (falcon-mamba-7b)
# ============================================================================
def mamba1_init(rng, cfg, dtype):
    d, di, st, dtr, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv
    keys = jax.random.split(rng, 6)
    A = jnp.tile(jnp.arange(1, st + 1, dtype=jnp.float32)[None, :], (di, 1))
    return {
        "in_proj": dense_init(keys[0], (d, 2 * di), dtype),
        "conv_w": dense_init(keys[1], (k, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(keys[2], (di, dtr + 2 * st), dtype),
        "dt_proj": dense_init(keys[3], (dtr, di), dtype),
        "dt_bias": jnp.full((di,), -2.0, dtype),  # softplus^-1(~0.12)
        "A_log": jnp.log(A),                       # f32
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(keys[4], (di, d), dtype),
    }


def _mamba1_ssm_inputs(p, x_conv, cfg):
    dtr, st = cfg.dt_rank, cfg.ssm_state
    x_db = jnp.einsum("bsc,ce->bse", x_conv, p["x_proj"])
    dt, Bm, Cm = jnp.split(x_db, [dtr, dtr + st], axis=-1)
    dt = _softplus(jnp.einsum("bsr,rc->bsc", dt, p["dt_proj"]).astype(jnp.float32)
                   + p["dt_bias"].astype(jnp.float32))           # (B,S,di)
    A = -jnp.exp(p["A_log"])                                     # (di, st)
    a = jnp.exp(dt[..., None] * A)                               # (B,S,di,st)
    b = (dt * x_conv.astype(jnp.float32))[..., None] * Bm.astype(jnp.float32)[:, :, None, :]
    return a, b, Cm


def _scan_dtype(cfg):
    return jnp.dtype(getattr(cfg, "ssm_scan_dtype", "float32"))


def mamba1_apply(p, x, cfg, state=None):
    """x (B,S,d). state: None (train, h0=0) or dict(conv, h) for chunk-carry."""
    B, S, _ = x.shape
    di, st = cfg.d_inner, cfg.ssm_state
    xz = shard_tokens(jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    x_in, z = jnp.split(xz, 2, axis=-1)
    x_conv = jax.nn.silu(_causal_conv(x_in, p["conv_w"], p["conv_b"]))
    a, b, Cm = _mamba1_ssm_inputs(p, x_conv, cfg)
    sdt = _scan_dtype(cfg)
    a, b = a.astype(sdt), b.astype(sdt)
    h0 = jnp.zeros((B, di, st), sdt)
    unroll = True if cfg.unroll_scans else 1
    if cfg.fused_ssm_y:
        y, _ = _assoc_scan_fused(
            a, b, h0, Cm.astype(sdt), cfg.ssm_chunk,
            lambda hc, cc: jnp.einsum("bscn,bsn->bsc", hc, cc,
                                      preferred_element_type=jnp.float32),
            unroll=unroll)
    else:
        hs, _ = _assoc_scan_chunked(a, b, h0, cfg.ssm_chunk, unroll=unroll)
        y = jnp.einsum("bscn,bsn->bsc", hs, Cm.astype(hs.dtype),
                   preferred_element_type=jnp.float32)
    y = y + p["D"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return shard_tokens(jnp.einsum("bsc,cd->bsd", y, p["out_proj"]))


def mamba1_decode(p, x, state, cfg):
    """x (B,1,d); state dict(conv (B,K-1,di), h (B,di,st)) -> (y, state)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]
    x_in, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _conv_step(state["conv"], x_in, p["conv_w"], p["conv_b"])
    x_conv = jax.nn.silu(xc)
    a, b, Cm = _mamba1_ssm_inputs(p, x_conv[:, None, :], cfg)
    h = a[:, 0] * state["h"] + b[:, 0]
    y = jnp.einsum("bcn,bn->bc", h, Cm[:, 0].astype(jnp.float32))
    y = y + p["D"] * x_conv.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bc,cd->bd", y, p["out_proj"])[:, None, :]
    return out, {"conv": conv_state, "h": h}


def mamba1_state_init(batch, cfg, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner), dtype),
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
    }


# ============================================================================
# Mamba2 (zamba2-7b)
# ============================================================================
def mamba2_init(rng, cfg, dtype):
    d, di, st, nh, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv
    keys = jax.random.split(rng, 4)
    conv_ch = di + 2 * st
    return {
        "in_proj": dense_init(keys[0], (d, 2 * di + 2 * st + nh), dtype),
        "conv_w": dense_init(keys[1], (k, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.full((nh,), -2.0, jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(keys[2], (di, d), dtype),
    }


def _mamba2_split(p, x, cfg):
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = shard_tokens(jnp.einsum("bsd,de->bse", x, p["in_proj"]))
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * st]
    dt = zxbcdt[..., di + di + 2 * st:]
    return z, xbc, dt


def _mamba2_ssm(p, xbc_conv, dt, cfg):
    di, st, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xh = xbc_conv[..., :di]
    Bm = xbc_conv[..., di:di + st].astype(jnp.float32)
    Cm = xbc_conv[..., di + st:].astype(jnp.float32)
    dt = _softplus(dt.astype(jnp.float32) + p["dt_bias"])        # (B,S,nh)
    A = -jnp.exp(p["A_log"])                                      # (nh,)
    a = jnp.exp(dt * A)                                           # (B,S,nh)
    xheads = xh.reshape(xh.shape[:-1] + (nh, hd)).astype(jnp.float32)
    # b_t = dt * x_t ⊗ B_t : (B,S,nh,hd,st)
    b = (dt[..., None] * xheads)[..., None] * Bm[:, :, None, None, :]
    return a, b, Cm, xheads


def mamba2_apply(p, x, cfg):
    B, S, _ = x.shape
    nh, hd, st = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z, xbc, dt = _mamba2_split(p, x, cfg)
    xbc_conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    a, b, Cm, xheads = _mamba2_ssm(p, xbc_conv, dt, cfg)
    sdt = _scan_dtype(cfg)
    a, b = a.astype(sdt), b.astype(sdt)
    Cm = Cm.astype(sdt)
    h0 = jnp.zeros((B, nh, hd, st), sdt)
    a_b = jnp.broadcast_to(a[..., None, None], b.shape)
    unroll = True if cfg.unroll_scans else 1
    if cfg.fused_ssm_y:
        y, _ = _assoc_scan_fused(
            a_b, b, h0, Cm, cfg.ssm_chunk,
            lambda hc, cc: jnp.einsum("bshdn,bsn->bshd", hc, cc,
                                      preferred_element_type=jnp.float32),
            unroll=unroll)
    else:
        hs, _ = _assoc_scan_chunked(a_b, b, h0, cfg.ssm_chunk, unroll=unroll)
        y = jnp.einsum("bshdn,bsn->bshd", hs, Cm,
                   preferred_element_type=jnp.float32)
    y = y + p["D"][:, None] * xheads
    y = y.reshape(B, S, nh * hd)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"], cfg.norm_eps)
    return shard_tokens(jnp.einsum("bsc,cd->bsd", y.astype(x.dtype), p["out_proj"]))


def mamba2_decode(p, x, state, cfg):
    nh, hd = cfg.ssm_heads, cfg.ssm_head_dim
    B = x.shape[0]
    z, xbc, dt = _mamba2_split(p, x, cfg)
    xc, conv_state = _conv_step(state["conv"], xbc[:, 0], p["conv_w"], p["conv_b"])
    xbc_conv = jax.nn.silu(xc)[:, None, :]
    a, b, Cm, xheads = _mamba2_ssm(p, xbc_conv, dt, cfg)
    h = a[:, 0][..., None, None] * state["h"] + b[:, 0]
    y = jnp.einsum("bhdn,bn->bhd", h, Cm[:, 0])
    y = y + p["D"][:, None] * xheads[:, 0]
    y = y.reshape(B, 1, nh * hd)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)), p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bsc,cd->bsd", y.astype(x.dtype), p["out_proj"])
    return out, {"conv": conv_state, "h": h}


def mamba2_state_init(batch, cfg, dtype):
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner + 2 * cfg.ssm_state), dtype),
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }


# ----------------------------------------------------------------------------
# Sequential-scan oracle (tests compare the chunked path against this)
# ----------------------------------------------------------------------------
def reference_scan(a, b, h0):
    def step(h, ab):
        a_t, b_t = ab
        h = a_t * h + b_t
        return h, h
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(a, 1, 0), jnp.moveaxis(b, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)
