"""Shared layers: norms, rotary embeddings, SwiGLU MLP, embedding, loss."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.context import shard_ff, shard_tokens


def maybe_remat(fn, cfg):
    """Rematerialization policy for the layer scan body (perf knob)."""
    mode = getattr(cfg, "remat_mode", "dots")
    if not cfg.remat or mode == "none":
        return fn
    policy = (jax.checkpoint_policies.nothing_saveable if mode == "nothing"
              else jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn, policy=policy)


def scan_unroll(cfg):
    """lax.scan unroll amount: full unroll in analysis mode so XLA cost
    analysis counts every layer/chunk (scan bodies are otherwise counted
    once — see launch/dryrun.py)."""
    return True if getattr(cfg, "unroll_scans", False) else 1


def _cache_dtype(cfg):
    """KV/state cache dtype follows the model compute dtype."""
    return jnp.dtype(cfg.dtype)


def truncated_normal_init(rng, shape, scale, dtype):
    fan_in = shape[0] if len(shape) >= 1 else 1
    std = scale / np.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def dense_init(rng, d_in_shape, dtype):
    """He-style init where fan_in is the product of all leading dims but the last."""
    fan_in = int(np.prod(d_in_shape[:-1])) if len(d_in_shape) > 1 else d_in_shape[0]
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(rng, d_in_shape, jnp.float32) * std).astype(dtype)


def rms_norm(x, weight, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)).astype(dt)


# ----------------------------------------------------------------------------
# Rotary position embeddings (rotate-half / NeoX convention)
# ----------------------------------------------------------------------------
def rope_sincos(positions, head_dim: int, theta: float):
    """positions: (...,) int32 -> sin, cos of shape (..., head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(angles), jnp.cos(angles)


def apply_rope(x, sin, cos):
    """x: (..., S, n_heads, head_dim); sin/cos: (..., S, head_dim/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    s = sin[..., None, :]  # broadcast over heads axis
    c = cos[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1).astype(x.dtype)


def sinusoidal_positions(n_pos: int, d_model: int):
    """Classic transformer sin/cos absolute position table (no params)."""
    pos = np.arange(n_pos)[:, None]
    dim = np.arange(0, d_model, 2)[None, :]
    angle = pos / np.power(10000.0, dim / d_model)
    table = np.zeros((n_pos, d_model), np.float32)
    table[:, 0::2] = np.sin(angle)
    table[:, 1::2] = np.cos(angle)
    return jnp.asarray(table)


# ----------------------------------------------------------------------------
# SwiGLU MLP
# ----------------------------------------------------------------------------
def mlp_init(rng, d_model: int, d_ff: int, dtype):
    kg, ki, ko = jax.random.split(rng, 3)
    return {
        "wg": dense_init(kg, (d_model, d_ff), dtype),
        "wi": dense_init(ki, (d_model, d_ff), dtype),
        "wo": dense_init(ko, (d_ff, d_model), dtype),
    }


def mlp_apply(p, x):
    g = shard_ff(jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wg"])))
    u = shard_ff(jnp.einsum("...d,df->...f", x, p["wi"]))
    return shard_tokens(jnp.einsum("...f,fd->...d", g * u, p["wo"]))


# ----------------------------------------------------------------------------
# Embedding + LM head + loss
# ----------------------------------------------------------------------------
def embed_init(rng, vocab: int, d_model: int, dtype, tie: bool):
    ke, kh = jax.random.split(rng)
    p = {"embedding": truncated_normal_init(ke, (vocab, d_model), 1.0, dtype)}
    if not tie:
        p["lm_head"] = dense_init(kh, (d_model, vocab), dtype)
    return p


def embed_apply(p, tokens):
    return shard_tokens(jnp.take(p["embedding"], tokens, axis=0))


def logits_apply(p, x, tie: bool):
    if tie:
        return shard_ff(jnp.einsum("...d,vd->...v", x, p["embedding"]))
    return shard_ff(jnp.einsum("...d,dv->...v", x, p["lm_head"]))


def cross_entropy_loss(logits, labels, mask=None):
    """Mean token-level CE. logits (..., V) any float dtype; stable in f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - ll
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
