"""Whisper-style encoder-decoder.  The conv/log-mel frontend is a STUB per the
assignment: ``batch["frames"]`` carries precomputed frame embeddings
(B, n_frames, d_model).  Sinusoidal absolute positions (no 32k learned table —
documented adaptation).  Decoder layers: causal self-attn (KV cache) +
cross-attn (encoder KV computed once at prefill) + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_heads, shard_tokens
from repro.models import attention as attn
from repro.models.attention import AttnMode
from repro.models.layers import (
    cross_entropy_loss, embed_apply, embed_init, logits_apply,
    maybe_remat, mlp_apply, mlp_init, rms_norm, scan_unroll, sinusoidal_positions,
    _cache_dtype,
)


def _xattn_init(rng, cfg, dtype):
    return attn.attn_init(rng, cfg.d_model, cfg.n_heads, cfg.n_heads,
                          cfg.head_dim, False, dtype)


def init(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ke, kenc, kdec = jax.random.split(rng, 3)

    def enc_layer(r):
        r1, r2 = jax.random.split(r)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": attn.attn_init(r1, cfg.d_model, cfg.n_heads, cfg.n_heads,
                                   cfg.head_dim, False, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": mlp_init(r2, cfg.d_model, cfg.d_ff, dtype),
        }

    def dec_layer(r):
        r1, r2, r3 = jax.random.split(r, 3)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "self": attn.attn_init(r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.head_dim, False, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "cross": _xattn_init(r2, cfg, dtype),
            "ln3": jnp.ones((cfg.d_model,), dtype),
            "mlp": mlp_init(r3, cfg.d_model, cfg.d_ff, dtype),
        }

    enc = jax.vmap(enc_layer)(jax.random.split(kenc, cfg.n_encoder_layers))
    dec = jax.vmap(dec_layer)(jax.random.split(kdec, cfg.n_layers))
    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "encoder": enc,
        "decoder": dec,
    }


def _posenc(x):
    pe = sinusoidal_positions(x.shape[1], x.shape[2]).astype(x.dtype)
    return x + pe[None]


def encode(params, cfg, frames, mode: AttnMode = AttnMode()):
    x = _posenc(frames.astype(jnp.dtype(cfg.dtype)))

    def body(xx, lp):
        h = rms_norm(xx, lp["ln1"], cfg.norm_eps)
        q = shard_heads(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"]))
        k = shard_heads(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"]))
        v = shard_heads(jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"]))
        o = attn.attend(q, k, v, causal=False, mode=mode)
        xx = xx + shard_tokens(jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"]))
        h = rms_norm(xx, lp["ln2"], cfg.norm_eps)
        return xx + mlp_apply(lp["mlp"], h), None

    fn = maybe_remat(body, cfg)
    x, _ = jax.lax.scan(fn, x, params["encoder"], unroll=scan_unroll(cfg))
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_kv(lp, enc_out):
    k = shard_heads(jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wk"]))
    v = shard_heads(jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross"]["wv"]))
    return k, v


def _dec_layer(lp, x, enc_out, cfg, mode, self_kv=None, write_pos=None,
               cross_kv=None):
    """One decoder layer; decode mode when self_kv (cache tensors) given."""
    h = rms_norm(x, lp["ln1"], cfg.norm_eps)
    if self_kv is None:
        b, s, _ = h.shape
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        q, k, v = attn.qkv_project(lp["self"], h, pos, cfg.rope_theta, False,
                                   cfg.norm_eps)
        o = attn.attend(q, k, v, causal=True, mode=mode)
        new_self = (k, v)
    else:
        q, k, v = attn.qkv_project(lp["self"], h, write_pos[:, None],
                                   cfg.rope_theta, False, cfg.norm_eps)
        ck, cv = attn.cache_update(self_kv[0], self_kv[1], k, v, write_pos)
        o = attn.attend_decode(q, ck, cv, write_pos + 1)
        new_self = (ck, cv)
    x = x + shard_tokens(jnp.einsum("bshk,hkd->bsd", o, lp["self"]["wo"]))

    h = rms_norm(x, lp["ln2"], cfg.norm_eps)
    q = shard_heads(jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"]))
    if cross_kv is None:
        ek, ev = _cross_kv(lp, enc_out)
    else:
        ek, ev = cross_kv
    if self_kv is None:
        o = attn.attend(q, ek, ev, causal=False, mode=mode)
    else:
        lengths = jnp.full((q.shape[0],), ek.shape[1], jnp.int32)
        o = attn.attend_decode(q, ek, ev, lengths)
    x = x + shard_tokens(jnp.einsum("bshk,hkd->bsd", o, lp["cross"]["wo"]))

    h = rms_norm(x, lp["ln3"], cfg.norm_eps)
    return x + mlp_apply(lp["mlp"], h), new_self


def forward(params, cfg, batch, mode: AttnMode = AttnMode()):
    """batch: frames (B,F,d), tokens (B,S) -> logits (B,S,V)."""
    enc_out = encode(params, cfg, batch["frames"], mode)
    x = _posenc(embed_apply(params["embed"], batch["tokens"]))

    def body(xx, lp):
        xx, _ = _dec_layer(lp, xx, enc_out, cfg, mode)
        return xx, None

    fn = maybe_remat(body, cfg)
    x, _ = jax.lax.scan(fn, x, params["decoder"], unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_apply(params["embed"], x, cfg.tie_embeddings)


def loss_fn(params, cfg, batch, mode: AttnMode = AttnMode()):
    logits = forward(params, cfg, batch, mode)
    return cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                              batch.get("loss_mask"))


def cache_init(cfg, batch_size: int, smax: int, dtype=None):
    dtype = dtype or _cache_dtype(cfg)
    L = cfg.n_layers
    self_shape = (L, batch_size, smax, cfg.n_kv_heads, cfg.head_dim)
    cross_shape = (L, batch_size, cfg.n_encoder_frames, cfg.n_heads, cfg.head_dim)
    return {"k": jnp.zeros(self_shape, dtype), "v": jnp.zeros(self_shape, dtype),
            "xk": jnp.zeros(cross_shape, dtype), "xv": jnp.zeros(cross_shape, dtype)}


def prefill(params, cfg, batch, smax: int, mode: AttnMode = AttnMode()):
    enc_out = encode(params, cfg, batch["frames"], mode)
    x = _posenc(embed_apply(params["embed"], batch["tokens"]))
    b, s, _ = x.shape
    cache = cache_init(cfg, b, smax)

    def body(xx, lp):
        xx, (k, v) = _dec_layer(lp, xx, enc_out, cfg, mode)
        xk, xv = _cross_kv(lp, enc_out)
        return xx, (k, v, xk, xv)

    x, (ks, vs, xks, xvs) = jax.lax.scan(body, x, params["decoder"],
                                         unroll=scan_unroll(cfg))
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)
    cache["xk"] = xks.astype(cache["xk"].dtype)
    cache["xv"] = xvs.astype(cache["xv"].dtype)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return cache, logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]


def decode_step(params, cfg, batch, cache):
    tokens, positions = batch["tokens"], batch["positions"]
    x = embed_apply(params["embed"], tokens)
    pe = sinusoidal_positions(cache["k"].shape[2], cfg.d_model)
    x = x + pe[positions][:, None].astype(x.dtype)

    def body(xx, xs):
        lp, ck, cv, xk, xv = xs
        xx, (nk, nv) = _dec_layer(lp, xx, None, cfg, AttnMode(),
                                  self_kv=(ck, cv), write_pos=positions,
                                  cross_kv=(xk, xv))
        return xx, (nk, nv)

    x, (nk, nv) = jax.lax.scan(
        body, x, (params["decoder"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]), unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
