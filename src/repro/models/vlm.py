"""InternVL2-style VLM: the InternViT frontend is a STUB per the assignment —
``batch["prefix_embeds"]`` carries post-projection patch embeddings
(B, n_patches, d_model) which are prepended to the token stream of the
qwen2-style LM backbone (see models/transformer.py).  Loss is masked to text
positions.  Decode: patch embeddings live in the prefix of the KV cache.
"""
from __future__ import annotations


from repro.models import transformer as tf
from repro.models.attention import AttnMode

init = tf.init


def forward(params, cfg, batch, mode: AttnMode = AttnMode()):
    return tf.forward(params, cfg, batch, mode)


def loss_fn(params, cfg, batch, mode: AttnMode = AttnMode()):
    return tf.loss_fn(params, cfg, batch, mode)


def cache_init(cfg, batch_size, smax, dtype=None):
    return tf.cache_init(cfg, batch_size, smax, dtype)


def prefill(params, cfg, batch, smax: int, mode: AttnMode = AttnMode()):
    """Prompt = [patch embeddings; prompt tokens]."""
    return tf.prefill(params, cfg, batch, smax, mode)


def decode_step(params, cfg, batch, cache):
    return tf.decode_step(params, cfg, batch, cache)
