"""Uniform model API across families + batch construction helpers.

Every family exposes:  init(rng,cfg) / forward / loss_fn / prefill /
decode_step / cache_init  with dict batches, so steps, the trainer, the
serving engine and the dry-run treat all 10 archs identically.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import encdec, hybrid, ssm_lm, transformer, vlm


class ModelApi(NamedTuple):
    init: Callable
    forward: Callable
    loss_fn: Callable
    prefill: Callable
    decode_step: Callable
    cache_init: Callable


_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": vlm,
    "ssm": ssm_lm,
    "hybrid": hybrid,
    "audio": encdec,
}


def get_model(cfg) -> ModelApi:
    mod = _FAMILIES[cfg.family]
    return ModelApi(mod.init, mod.forward, mod.loss_fn, mod.prefill,
                    mod.decode_step, mod.cache_init)


# ----------------------------------------------------------------------------
# batch builders (concrete arrays for smoke tests / training, and
# ShapeDtypeStructs for the dry-run via abstract=True)
# ----------------------------------------------------------------------------
def train_batch_shapes(cfg, batch: int, seq: int) -> dict[str, Any]:
    shapes = {
        "tokens": ((batch, seq), jnp.int32),
        "labels": ((batch, seq), jnp.int32),
    }
    if cfg.family == "vlm":
        shapes["prefix_embeds"] = ((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        shapes["frames"] = ((batch, cfg.n_encoder_frames, cfg.d_model), jnp.bfloat16)
    return shapes


def decode_batch_shapes(cfg, batch: int) -> dict[str, Any]:
    return {
        "tokens": ((batch, 1), jnp.int32),
        "positions": ((batch,), jnp.int32),
    }


def prefill_batch_shapes(cfg, batch: int, seq: int) -> dict[str, Any]:
    shapes = {"tokens": ((batch, seq), jnp.int32)}
    if cfg.family == "vlm":
        shapes["prefix_embeds"] = ((batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        shapes["frames"] = ((batch, cfg.n_encoder_frames, cfg.d_model), jnp.bfloat16)
    return shapes


def make_concrete_batch(shapes, rng: np.random.Generator, vocab: int):
    out = {}
    for name, (shape, dtype) in shapes.items():
        if dtype == jnp.int32:
            hi = vocab if name in ("tokens", "labels") else max(np.prod(shape), 2)
            out[name] = jnp.asarray(rng.integers(0, hi, size=shape, dtype=np.int32))
        else:
            out[name] = jnp.asarray(rng.normal(size=shape), dtype=dtype)
    return out


def eval_params_shape(cfg, rng_seed: int = 0):
    """Parameter ShapeDtypeStructs without allocating (for the dry-run)."""
    api = get_model(cfg)
    return jax.eval_shape(lambda k: api.init(k, cfg), jax.random.key(rng_seed))


def eval_cache_shape(cfg, batch: int, smax: int):
    api = get_model(cfg)
    return jax.eval_shape(lambda: api.cache_init(cfg, batch, smax))
