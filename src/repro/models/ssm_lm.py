"""Falcon-mamba-style attention-free LM: a stack of Mamba1 blocks."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import ssm
from repro.models.attention import AttnMode
from repro.models.layers import (
    cross_entropy_loss, embed_apply, embed_init, logits_apply, maybe_remat,
    rms_norm, scan_unroll, _cache_dtype,
)


def init(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ke, kl = jax.random.split(rng)

    def layer(r):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                **ssm.mamba1_init(r, cfg, dtype)}

    layers = jax.vmap(layer)(jax.random.split(kl, cfg.n_layers))
    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "layers": layers,
    }


def forward(params, cfg, batch, mode: AttnMode = AttnMode()):
    x = embed_apply(params["embed"], batch["tokens"])

    def body(xx, lp):
        h = rms_norm(xx, lp["ln"], cfg.norm_eps)
        return xx + ssm.mamba1_apply(lp, h, cfg), None

    fn = maybe_remat(body, cfg)
    x, _ = jax.lax.scan(fn, x, params["layers"], unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_apply(params["embed"], x, cfg.tie_embeddings)


def loss_fn(params, cfg, batch, mode: AttnMode = AttnMode()):
    logits = forward(params, cfg, batch, mode)
    return cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                              batch.get("loss_mask"))


def cache_init(cfg, batch_size: int, smax: int, dtype=None):
    dtype = dtype or _cache_dtype(cfg)
    st = ssm.mamba1_state_init(batch_size, cfg, dtype)
    return jax.tree.map(
        lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype), st)


def prefill(params, cfg, batch, smax: int, mode: AttnMode = AttnMode()):
    x = embed_apply(params["embed"], batch["tokens"])
    b = x.shape[0]

    def body(xx, lp):
        h = rms_norm(xx, lp["ln"], cfg.norm_eps)
        y = ssm.mamba1_apply(lp, h, cfg)
        # final state (cheap second pass over SSM inputs for the carry)
        xz = jnp.einsum("bsd,de->bse", h, lp["in_proj"])
        x_in, _ = jnp.split(xz, 2, axis=-1)
        x_conv = jax.nn.silu(ssm._causal_conv(x_in, lp["conv_w"], lp["conv_b"]))
        a, bb, _ = ssm._mamba1_ssm_inputs(lp, x_conv, cfg)
        h0 = jnp.zeros((b, cfg.d_inner, cfg.ssm_state), jnp.float32)
        _, hfin = ssm._assoc_scan_chunked(a, bb, h0, cfg.ssm_chunk,
                                          unroll=True if cfg.unroll_scans else 1)
        km1 = cfg.ssm_conv - 1
        xp = jnp.pad(x_in, ((0, 0), (max(km1 - x_in.shape[1], 0), 0), (0, 0)))
        conv_fin = xp[:, -km1:, :]
        return xx + y, {"conv": conv_fin, "h": hfin}

    x, states = jax.lax.scan(body, x, params["layers"], unroll=scan_unroll(cfg))
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return states, logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]


def decode_step(params, cfg, batch, cache):
    x = embed_apply(params["embed"], batch["tokens"])

    def body(xx, xs):
        lp, st = xs
        h = rms_norm(xx, lp["ln"], cfg.norm_eps)
        y, nst = ssm.mamba1_decode(lp, h, st, cfg)
        return xx + y, nst

    x, nstates = jax.lax.scan(body, x, (params["layers"], cache),
                              unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, nstates
