from repro.models.registry import (
    ModelApi,
    decode_batch_shapes,
    eval_cache_shape,
    eval_params_shape,
    get_model,
    make_concrete_batch,
    prefill_batch_shapes,
    train_batch_shapes,
)

__all__ = [
    "ModelApi", "get_model", "train_batch_shapes", "decode_batch_shapes",
    "prefill_batch_shapes", "make_concrete_batch", "eval_params_shape",
    "eval_cache_shape",
]
