"""Zamba2-style hybrid: a stack of Mamba2 blocks with ONE shared
attention+MLP transformer block (single weight copy) applied every
``cfg.shared_attn_period`` blocks.

Layout: n_layers = G groups × P layers (P = shared_attn_period).  Each group
starts with the shared block application (its own KV cache slot), followed by
P Mamba2 blocks.  Outer scan over groups, inner scan over the group's Mamba2
layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_tokens
from repro.models import attention as attn
from repro.models import ssm
from repro.models.attention import AttnMode
from repro.models.layers import (
    cross_entropy_loss, embed_apply, embed_init, logits_apply,
    maybe_remat, mlp_apply, mlp_init, rms_norm, scan_unroll, _cache_dtype,
)


def _groups(cfg):
    p = cfg.shared_attn_period
    assert p > 0 and cfg.n_layers % p == 0, (cfg.n_layers, p)
    return cfg.n_layers // p, p


def init(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ke, ks, ka, km = jax.random.split(rng, 4)
    G, P = _groups(cfg)

    def ssm_layer(r):
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                **ssm.mamba2_init(r, cfg, dtype)}

    layers = jax.vmap(ssm_layer)(jax.random.split(ks, G * P))
    layers = jax.tree.map(lambda a: a.reshape((G, P) + a.shape[1:]), layers)

    shared = {
        "ln1": jnp.ones((cfg.d_model,), dtype),
        "attn": attn.attn_init(ka, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                               cfg.head_dim, False, dtype),
        "ln2": jnp.ones((cfg.d_model,), dtype),
        "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, dtype),
    }
    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "shared": shared,
        "layers": layers,
    }


def _shared_block(shared, x, positions, cfg, mode, cache=None, write_pos=None):
    h = rms_norm(x, shared["ln1"], cfg.norm_eps)
    q, k, v = attn.qkv_project(shared["attn"], h, positions, cfg.rope_theta,
                               False, cfg.norm_eps)
    if cache is None:
        o = attn.attend(q, k, v, causal=True, mode=mode)
        new_cache = (k, v)
    else:
        ck, cv = attn.cache_update(cache[0], cache[1], k, v, write_pos)
        o = attn.attend_decode(q, ck, cv, write_pos + 1)
        new_cache = (ck, cv)
    x = x + shard_tokens(jnp.einsum("bshk,hkd->bsd", o, shared["attn"]["wo"]))
    h = rms_norm(x, shared["ln2"], cfg.norm_eps)
    return x + mlp_apply(shared["mlp"], h), new_cache


def _group_fwd(shared, glayers, x, positions, cfg, mode):
    x, kv = _shared_block(shared, x, positions, cfg, mode)

    def body(xx, lp):
        h = rms_norm(xx, lp["ln"], cfg.norm_eps)
        return xx + ssm.mamba2_apply(lp, h, cfg), None

    x, _ = jax.lax.scan(body, x, glayers, unroll=scan_unroll(cfg))
    return x, kv


def forward(params, cfg, batch, mode: AttnMode = AttnMode()):
    tokens = batch["tokens"]
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))

    def gbody(xx, glayers):
        fn = maybe_remat(
            lambda xc, gl: _group_fwd(params["shared"], gl, xc, positions, cfg, mode),
            cfg)
        xx, _ = fn(xx, glayers)
        return xx, None

    x, _ = jax.lax.scan(gbody, x, params["layers"], unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_apply(params["embed"], x, cfg.tie_embeddings)


def loss_fn(params, cfg, batch, mode: AttnMode = AttnMode()):
    logits = forward(params, cfg, batch, mode)
    return cross_entropy_loss(logits[:, :-1], batch["labels"][:, 1:],
                              batch.get("loss_mask"))


# ----------------------------------------------------------------------------
# cache: per-group shared-attn KV + per-layer mamba2 state
# ----------------------------------------------------------------------------
def cache_init(cfg, batch_size: int, smax: int, dtype=None):
    dtype = dtype or _cache_dtype(cfg)
    G, P = _groups(cfg)
    kvshape = (G, batch_size, smax, cfg.n_kv_heads, cfg.head_dim)
    st = ssm.mamba2_state_init(batch_size, cfg, dtype)
    return {
        "k": jnp.zeros(kvshape, dtype),
        "v": jnp.zeros(kvshape, dtype),
        "ssm": jax.tree.map(
            lambda a: jnp.zeros((G, P) + a.shape, a.dtype), st),
    }


def prefill(params, cfg, batch, smax: int, mode: AttnMode = AttnMode()):
    """Prompt pass producing decode state.  For the SSM layers we run the
    chunked scan and keep only the final state; shared-attn KV is padded into
    the cache."""
    tokens = batch["tokens"]
    x = embed_apply(params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    cache = cache_init(cfg, b, smax)

    def gbody(xx, xs):
        glayers, _ = xs
        xx, (k, v) = _shared_block(params["shared"], xx, positions, cfg, mode)

        def lbody(xc, lp):
            h = rms_norm(xc, lp["ln"], cfg.norm_eps)
            # full apply; recompute final state via one-chunk scan on the fly
            y = ssm.mamba2_apply(lp, h, cfg)
            # final ssm state: rerun split to get state (cheap relative to apply)
            z, xbc, dt = ssm._mamba2_split(lp, h, cfg)
            xbc_conv = jax.nn.silu(ssm._causal_conv(xbc, lp["conv_w"], lp["conv_b"]))
            a, bb, _, _ = ssm._mamba2_ssm(lp, xbc_conv, dt, cfg)
            h0 = jnp.zeros((b, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
            _, hfin = ssm._assoc_scan_chunked(
                jnp.broadcast_to(a[..., None, None], bb.shape), bb, h0, cfg.ssm_chunk,
                unroll=True if cfg.unroll_scans else 1)
            km1 = cfg.ssm_conv - 1
            xbp = jnp.pad(xbc, ((0, 0), (max(km1 - xbc.shape[1], 0), 0), (0, 0)))
            conv_fin = xbp[:, -km1:, :]
            return xc + y, {"conv": conv_fin.astype(cache["ssm"]["conv"].dtype), "h": hfin}

        xx, states = jax.lax.scan(lbody, xx, glayers, unroll=scan_unroll(cfg))
        return xx, (k, v, states)

    x, (ks, vs, states) = jax.lax.scan(gbody, x,
                                       (params["layers"], jnp.arange(_groups(cfg)[0])),
                                       unroll=scan_unroll(cfg))
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, axis=2)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, axis=2)
    cache["ssm"] = states
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return cache, logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]


def decode_step(params, cfg, batch, cache):
    tokens, positions = batch["tokens"], batch["positions"]
    x = embed_apply(params["embed"], tokens)

    def gbody(xx, xs):
        glayers, ck, cv, gstate = xs
        xx, (nk, nv) = _shared_block(params["shared"], xx, positions[:, None],
                                     cfg, AttnMode(), cache=(ck, cv),
                                     write_pos=positions)

        def lbody(xc, lxs):
            lp, lstate = lxs
            h = rms_norm(xc, lp["ln"], cfg.norm_eps)
            y, nstate = ssm.mamba2_decode(lp, h, lstate, cfg)
            return xc + y, nstate

        xx, nstates = jax.lax.scan(lbody, xx, (glayers, gstate),
                                   unroll=scan_unroll(cfg))
        return xx, (nk, nv, nstates)

    x, (nk, nv, nstates) = jax.lax.scan(
        gbody, x, (params["layers"], cache["k"], cache["v"], cache["ssm"]),
        unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, {"k": nk, "v": nv, "ssm": nstates}
