"""Attention: GQA with RoPE and optional qk-norm.

Three execution paths, mathematically identical:
  * ``attend_full``      — naive softmax attention (small seq / oracle)
  * ``attend_blockwise`` — flash-style online-softmax over KV blocks in pure
                           jnp (train/prefill default; this is also the
                           mathematical spec of the Pallas kernel)
  * kernels/flash_attention — the Pallas TPU kernel (validated vs ref)

Decode path attends one new token against a padded KV cache with per-batch
lengths.
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp

from repro.distributed.context import shard_heads
from repro.models.layers import apply_rope, dense_init, rms_norm, rope_sincos

NEG_INF = -1e30


def attn_init(rng, d_model, n_heads, n_kv_heads, head_dim, qk_norm, dtype):
    kq, kk, kv, ko = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(kq, (d_model, n_heads, head_dim), dtype),
        "wk": dense_init(kk, (d_model, n_kv_heads, head_dim), dtype),
        "wv": dense_init(kv, (d_model, n_kv_heads, head_dim), dtype),
        "wo": dense_init(ko, (n_heads, head_dim, d_model), dtype),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), dtype)
        p["k_norm"] = jnp.ones((head_dim,), dtype)
    return p


def qkv_project(p, x, positions, theta, qk_norm, norm_eps):
    """x (B,S,d) -> q (B,S,H,hd), k/v (B,S,K,hd) with RoPE applied."""
    q = shard_heads(jnp.einsum("bsd,dhk->bshk", x, p["wq"]))
    k = shard_heads(jnp.einsum("bsd,dhk->bshk", x, p["wk"]))
    v = shard_heads(jnp.einsum("bsd,dhk->bshk", x, p["wv"]))
    if qk_norm:
        q = rms_norm(q, p["q_norm"], norm_eps)
        k = rms_norm(k, p["k_norm"], norm_eps)
    sin, cos = rope_sincos(positions, q.shape[-1], theta)
    return apply_rope(q, sin, cos), apply_rope(k, sin, cos), v


def _group(q, n_kv):
    """(B,S,H,hd) -> (B,S,K,G,hd)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, d)


def attend_full(q, k, v, *, causal=True, kv_valid=None):
    """Naive attention. q (B,Sq,H,hd), k/v (B,Sk,K,hd)."""
    n_kv = k.shape[2]
    qg = _group(q, n_kv)
    scale = q.shape[-1] ** -0.5
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32) * scale
    sq, sk = q.shape[1], k.shape[1]
    if causal:
        # query i may attend key j iff j <= i + (Sk - Sq)  (aligned suffixes)
        qi = jnp.arange(sq)[:, None] + (sk - sq)
        kj = jnp.arange(sk)[None, :]
        scores = jnp.where(kj <= qi, scores, NEG_INF)
    if kv_valid is not None:  # (B, Sk) bool
        scores = jnp.where(kv_valid[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(q.shape)


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps blocking exact for
    lengths like 4352 = 4096 tokens + 256 VLM patches)."""
    b = min(n, target)
    while n % b:
        b -= 1
    return b


def attend_blockwise(q, k, v, *, causal=True, q_block=512, kv_block=512,
                     causal_skip=False, unroll=1):
    """Flash-style online-softmax attention in pure jnp.

    Scans KV blocks per query block carrying (m, l, acc). ``causal_skip``
    replaces the masked full (i,j) sweep with a triangular (j<=i) pair scan —
    the beyond-paper optimization that halves attention FLOPs (see §Perf).
    """
    b, sq, h, hd = q.shape
    sk, n_kv = k.shape[1], k.shape[2]
    g = h // n_kv
    q_block = _pick_block(sq, q_block)
    kv_block = _pick_block(sk, kv_block)
    tq, tk = sq // q_block, sk // kv_block
    scale = hd ** -0.5

    qg = _group(q, n_kv).reshape(b, tq, q_block, n_kv, g, hd)
    kb = k.reshape(b, tk, kv_block, n_kv, hd)
    vb = v.reshape(b, tk, kv_block, n_kv, hd)
    offset = sk - sq  # suffix alignment for causal masking

    def block_scores(qi, kj, i, j):
        s = jnp.einsum("bqkgd,btkd->bkgqt", qi, kj).astype(jnp.float32) * scale
        if causal:
            rows = i * q_block + jnp.arange(q_block)[:, None] + offset
            cols = j * kv_block + jnp.arange(kv_block)[None, :]
            s = jnp.where(cols <= rows, s, NEG_INF)
        return s

    def online(carry, s, vj):
        m, l, acc = carry
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqt,btkd->bkgqd", p.astype(vj.dtype), vj).astype(jnp.float32)
        return m_new, l, acc

    def per_qblock(i, qi):
        m0 = jnp.full((b, n_kv, g, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, n_kv, g, q_block), jnp.float32)
        a0 = jnp.zeros((b, n_kv, g, q_block, hd), jnp.float32)

        if causal and causal_skip:
            if unroll is True and isinstance(i, int):
                # analysis/unrolled mode: statically skip j > i blocks so the
                # HLO contains ONLY the upper-triangular work (measurable)
                carry = (m0, l0, a0)
                for j in range(i + 1):
                    carry = online(carry, block_scores(qi, kb[:, j], i, j),
                                   vb[:, j])
                m, l, acc = carry
            else:
                # runtime mode: lax.cond skips masked blocks' compute on TPU
                def body(carry, j):
                    def do(c):
                        return online(c, block_scores(qi, kb[:, j], i, j), vb[:, j])
                    carry = jax.lax.cond(j <= i, do, lambda c: c, carry)
                    return carry, None
                (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                              jnp.arange(tk), unroll=unroll)
        else:
            def body(carry, jkv):
                j, kj, vj = jkv
                return online(carry, block_scores(qi, kj, i, j), vj), None
            (m, l, acc), _ = jax.lax.scan(
                body, (m0, l0, a0),
                (jnp.arange(tk), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
                unroll=unroll)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b,k,g,q,d) -> (b,q,k,g,d) -> (b,q,h,d)
        return jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, q_block, h, hd)

    if causal and causal_skip and unroll is True:
        outs = jnp.stack([per_qblock(i, qg[:, i]) for i in range(tq)])
    else:
        def scan_q(_, iq):
            i, qi = iq
            return None, per_qblock(i, qi)

        _, outs = jax.lax.scan(scan_q, None,
                               (jnp.arange(tq), jnp.moveaxis(qg, 1, 0)),
                               unroll=unroll)
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd).astype(q.dtype)


def attend_decode(q, k_cache, v_cache, lengths):
    """q (B,1,H,hd) new-token queries vs padded cache (B,Smax,K,hd).
    lengths (B,) = number of valid cache entries (including the new token)."""
    n_kv = k_cache.shape[2]
    b, _, h, hd = q.shape
    qg = q.reshape(b, n_kv, h // n_kv, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bkgd,btkd->bkgt", qg, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(k_cache.shape[1])[None, :] < lengths[:, None]  # (B,Smax)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bkgt,btkd->bkgd", w, v_cache)
    return out.reshape(b, 1, h, hd)


def cache_update(k_cache, v_cache, k_new, v_new, positions):
    """Insert one token per sequence at ``positions`` (B,)."""
    b = k_new.shape[0]
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, positions].set(k_new[:, 0].astype(k_cache.dtype))
    v_cache = v_cache.at[bidx, positions].set(v_new[:, 0].astype(v_cache.dtype))
    return k_cache, v_cache


@dataclasses.dataclass(frozen=True)
class AttnMode:
    """How the attention core executes (wired from ModelConfig / ParallelConfig)."""
    kind: str = "blockwise"   # full | blockwise
    q_block: int = 512
    kv_block: int = 512
    causal_skip: bool = False
    unroll: bool = False      # analysis mode (see launch/dryrun.py)


def attend(q, k, v, *, causal, mode: AttnMode):
    if mode.kind == "full" or q.shape[1] <= mode.q_block:
        return attend_full(q, k, v, causal=causal)
    return attend_blockwise(q, k, v, causal=causal, q_block=mode.q_block,
                            kv_block=mode.kv_block, causal_skip=mode.causal_skip,
                            unroll=True if mode.unroll else 1)
