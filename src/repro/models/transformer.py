"""Decoder-only transformer LM covering the dense / moe / vlm families.

Layers are grouped into *superblocks* of ``cfg.moe_layer_period`` layers so a
single ``lax.scan`` handles interleaved MoE stacks (llama4: dense layer + MoE
layer per superblock) and homogeneous stacks (period=1) alike.  Per-superblock
params carry a leading (n_super, ...) axis; attention params additionally a
(period, ...) axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import shard_tokens
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models.attention import AttnMode
from repro.models.layers import (
    cross_entropy_loss, embed_apply, embed_init, logits_apply,
    mlp_apply, mlp_init, rms_norm, scan_unroll, _cache_dtype,
)


def _stacked(fn, rng, n, *args):
    return jax.vmap(lambda r: fn(r, *args))(jax.random.split(rng, n))


def _n_super(cfg):
    assert cfg.n_layers % cfg.moe_layer_period == 0
    return cfg.n_layers // cfg.moe_layer_period


def init(rng, cfg):
    dtype = jnp.dtype(cfg.dtype)
    ke, kb, kf = jax.random.split(rng, 3)
    ns, period = _n_super(cfg), cfg.moe_layer_period

    def attn_layer(r):
        r1, r2 = jax.random.split(r)
        return {
            "ln": jnp.ones((cfg.d_model,), dtype),
            **attn.attn_init(r1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                             cfg.head_dim, cfg.qk_norm, dtype),
        }

    blocks = {"attn": _stacked(attn_layer, kb, ns * period)}
    # reshape leading (ns*period) -> (ns, period)
    blocks["attn"] = jax.tree.map(
        lambda a: a.reshape((ns, period) + a.shape[1:]), blocks["attn"])

    kd, km = jax.random.split(kf)
    if cfg.n_experts:
        def moe_layer(r):
            return {"ln": jnp.ones((cfg.d_model,), dtype),
                    **moe_mod.moe_init(r, cfg, dtype)}
        blocks["moe"] = _stacked(moe_layer, km, ns)
        if period > 1:
            def dense_layer(r):
                return {"ln": jnp.ones((cfg.d_model,), dtype),
                        **mlp_init(r, cfg.d_model, cfg.d_ff_dense or cfg.d_ff, dtype)}
            dl = _stacked(dense_layer, kd, ns * (period - 1))
            blocks["mlp_dense"] = jax.tree.map(
                lambda a: a.reshape((ns, period - 1) + a.shape[1:]), dl)
    else:
        def dense_layer(r):
            return {"ln": jnp.ones((cfg.d_model,), dtype),
                    **mlp_init(r, cfg.d_model, cfg.d_ff, dtype)}
        blocks["mlp"] = _stacked(dense_layer, km, ns)

    return {
        "embed": embed_init(ke, cfg.vocab_size, cfg.d_model, dtype, cfg.tie_embeddings),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": blocks,
    }


# ----------------------------------------------------------------------------
# superblock bodies
# ----------------------------------------------------------------------------
def _attn_sub(p, x, positions, cfg, mode: AttnMode):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q, k, v = attn.qkv_project(p, h, positions, cfg.rope_theta, cfg.qk_norm, cfg.norm_eps)
    o = attn.attend(q, k, v, causal=True, mode=mode)
    return x + shard_tokens(jnp.einsum("bshk,hkd->bsd", o, p["wo"])), (k, v)


def _ffn_sub(p, x, cfg, is_moe):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    if is_moe:
        return x + moe_mod.moe_ffn(p, h, cfg)
    return x + mlp_apply(p, h)


def _superblock(blk, x, positions, cfg, mode):
    period = cfg.moe_layer_period
    kvs = []
    for j in range(period):
        ap = jax.tree.map(lambda a: a[j], blk["attn"])
        x, kv = _attn_sub(ap, x, positions, cfg, mode)
        kvs.append(kv)
        if cfg.n_experts and j == period - 1:
            x = _ffn_sub(blk["moe"], x, cfg, True)
        elif cfg.n_experts and period > 1:
            dp = jax.tree.map(lambda a: a[j], blk["mlp_dense"])
            x = _ffn_sub(dp, x, cfg, False)
        elif not cfg.n_experts:
            x = _ffn_sub(blk["mlp"], x, cfg, False)
    ks = jnp.stack([kv[0] for kv in kvs])  # (period, B, S, K, hd)
    vs = jnp.stack([kv[1] for kv in kvs])
    return x, (ks, vs)


from repro.models.layers import maybe_remat as _maybe_remat  # noqa: E402


def _embed_input(params, cfg, tokens, prefix_embeds):
    x = embed_apply(params["embed"], tokens)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return x, positions


def _trunk(params, cfg, x, positions, mode, collect_kv=False):
    body = _maybe_remat(
        lambda xx, blk: _superblock(blk, xx, positions, cfg, mode), cfg)

    def scan_body(xx, blk):
        xx, kv = body(xx, blk)
        return xx, (kv if collect_kv else None)

    if cfg.scan_layers:
        x, kvs = jax.lax.scan(scan_body, x, params["blocks"],
                              unroll=scan_unroll(cfg))
    else:
        kvs_l = []
        ns = _n_super(cfg)
        for i in range(ns):
            blk = jax.tree.map(lambda a: a[i], params["blocks"])
            x, kv = scan_body(x, blk)
            kvs_l.append(kv)
        kvs = (jax.tree.map(lambda *xs: jnp.stack(xs), *kvs_l)
               if collect_kv else None)
    return x, kvs


def forward(params, cfg, batch, mode: AttnMode = AttnMode()):
    """Training forward. batch: tokens (B,S) [+ prefix_embeds (B,P,d)].
    Returns logits (B, S(+P), V)."""
    x, positions = _embed_input(params, cfg, batch["tokens"],
                                batch.get("prefix_embeds"))
    x, _ = _trunk(params, cfg, x, positions, mode)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_apply(params["embed"], x, cfg.tie_embeddings)


def loss_fn(params, cfg, batch, mode: AttnMode = AttnMode()):
    logits = forward(params, cfg, batch, mode)
    prefix = batch.get("prefix_embeds")
    if prefix is not None:
        logits = logits[:, prefix.shape[1]:]
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    return cross_entropy_loss(logits[:, :-1], labels[:, 1:],
                              None if mask is None else mask[:, 1:])


# ----------------------------------------------------------------------------
# prefill / decode
# ----------------------------------------------------------------------------
def cache_init(cfg, batch_size: int, smax: int, dtype=None):
    dtype = dtype or _cache_dtype(cfg)
    ns, period = _n_super(cfg), cfg.moe_layer_period
    shape = (ns, period, batch_size, smax, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, cfg, batch, smax: int, mode: AttnMode = AttnMode()):
    """Full forward over the prompt; returns (cache, last-token logits)."""
    x, positions = _embed_input(params, cfg, batch["tokens"],
                                batch.get("prefix_embeds"))
    x, kvs = _trunk(params, cfg, x, positions, mode, collect_kv=True)
    ks, vs = kvs  # (ns, period, B, S, K, hd)
    cache = cache_init(cfg, x.shape[0], smax)
    cache["k"] = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], ks.astype(cache["k"].dtype), 0, axis=3)
    cache["v"] = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], vs.astype(cache["v"].dtype), 0, axis=3)
    x = rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    return cache, logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]


def decode_step(params, cfg, batch, cache):
    """batch: tokens (B,1), positions (B,) write index. Returns (logits, cache)."""
    tokens, positions = batch["tokens"], batch["positions"]
    x = embed_apply(params["embed"], tokens)
    pos2d = positions[:, None]

    def block(x, blk_and_cache):
        blk, ck, cv = blk_and_cache
        period = cfg.moe_layer_period
        nk, nv = [], []
        for j in range(period):
            ap = jax.tree.map(lambda a: a[j], blk["attn"])
            h = rms_norm(x, ap["ln"], cfg.norm_eps)
            q, k, v = attn.qkv_project(ap, h, pos2d, cfg.rope_theta,
                                       cfg.qk_norm, cfg.norm_eps)
            ckj, cvj = attn.cache_update(ck[j], cv[j], k, v, positions)
            o = attn.attend_decode(q, ckj, cvj, positions + 1)
            x = x + shard_tokens(jnp.einsum("bshk,hkd->bsd", o, ap["wo"]))
            nk.append(ckj); nv.append(cvj)
            if cfg.n_experts and j == period - 1:
                x = _ffn_sub(blk["moe"], x, cfg, True)
            elif cfg.n_experts and period > 1:
                dp = jax.tree.map(lambda a: a[j], blk["mlp_dense"])
                x = _ffn_sub(dp, x, cfg, False)
            elif not cfg.n_experts:
                x = _ffn_sub(blk["mlp"], x, cfg, False)
        return x, (jnp.stack(nk), jnp.stack(nv))

    def scan_body(x, xs):
        return block(x, xs)

    x, (nk, nv) = jax.lax.scan(scan_body, x,
                               (params["blocks"], cache["k"], cache["v"]),
                               unroll=scan_unroll(cfg))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = logits_apply(params["embed"], x, cfg.tie_embeddings)[:, 0]
    return logits, {"k": nk, "v": nv}
