"""Mixture-of-Experts FFN with capacity-based gather/scatter dispatch.

Baseline (paper-era) path: dense routing → sort-based slotting → scatter into
an (E, C, d) buffer → batched expert SwiGLU → gather-combine.  FLOPs are
O(top_k · tokens · d · f) plus routing; the dispatch itself is gather/scatter
(no one-hot einsum blow-up).  Expert weights carry an 'expert' leading axis
that the sharding rules map to the 'model' mesh axis (EP).

``moe_ffn`` is pure jnp (GSPMD decides dispatch comms).  The §Perf pass adds a
replicated-activation EP variant that removes the scatter/gather resharding —
see distributed/steps.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.layers import dense_init, mlp_apply, mlp_init


def moe_init(rng, cfg, dtype):
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    keys = jax.random.split(rng, 6)
    p = {
        "router": dense_init(keys[0], (d, E), jnp.float32),
        "wg": dense_init(keys[1], (E, d, f), dtype),
        "wi": dense_init(keys[2], (E, d, f), dtype),
        "wo": dense_init(keys[3], (E, f, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(keys[4], d, cfg.n_shared_experts * f, dtype)
        p["shared_gate"] = dense_init(keys[5], (d, 1), jnp.float32)
    return p


def capacity(n_tokens: int, cfg) -> int:
    c = int(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def route(p, x, cfg):
    """x (T, d) -> (expert_idx (T,k), gates (T,k) f32)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), p["router"])
    gates_all = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(gates_all, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    return idx, gates


def dispatch_indices(expert_idx, n_experts: int, cap: int):
    """Flattened (T*k,) expert assignment -> (expert, position) pairs.
    Positions >= cap are overflow (dropped by scatter/gather OOB modes).
    Stable within expert (sorted order)."""
    flat_e = expert_idx.reshape(-1)                       # (T*k,)
    order = jnp.argsort(flat_e, stable=True)              # token-pairs grouped by e
    sorted_e = flat_e[order]
    # position within the expert's group
    start = jnp.searchsorted(sorted_e, jnp.arange(n_experts), side="left")
    pos_sorted = jnp.arange(flat_e.shape[0]) - start[sorted_e]
    # undo the sort: position for pair i
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    return flat_e, pos  # (T*k,), (T*k,)


def moe_ffn(p, x, cfg):
    """x (..., d) -> (..., d). Flattens all leading dims into tokens.

    Dispatch is a 2D scatter into an (E, C, d) buffer constrained to
    P('model','data',None): experts over TP (EP) *and* capacity over DP —
    without the capacity constraint GSPMD replicates the expert matmuls
    across the data axis (observed 16x FLOP blowup in the dry-run)."""
    from repro.distributed.context import current_mesh, current_moe_impl
    mesh = current_mesh()
    if current_moe_impl() == "shardmap" and mesh is not None:
        return moe_ffn_shardmap(p, x, cfg, mesh)

    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    C = capacity(T, cfg)

    idx, gates = route(p, xt, cfg)                        # (T,k)
    e_of_pair, pos_of_pair = dispatch_indices(idx, E, C)  # (T*k,)
    token_of_pair = jnp.repeat(jnp.arange(T), k)

    # scatter tokens into the expert buffer (positions >= C are dropped)
    ebuf = jnp.zeros((E, C, d), xt.dtype)
    ebuf = ebuf.at[e_of_pair, pos_of_pair].set(xt[token_of_pair], mode="drop")
    ebuf = constrain(ebuf, "model", "data", None)

    # batched expert SwiGLU.  Weights are ZeRO-3/FSDP-sharded on d over
    # 'data'; gather them here (per layer, under scan) so the matmul shards
    # as (e->model, c->data) — otherwise GSPMD replicates the capacity dim
    # across 'data' instead (16x FLOP blowup, observed in the dry-run).
    wg = constrain(p["wg"], "model", None, None)
    wi = constrain(p["wi"], "model", None, None)
    wo = constrain(p["wo"], "model", None, None)
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, wg))
    u = jnp.einsum("ecd,edf->ecf", ebuf, wi)
    eout = jnp.einsum("ecf,efd->ecd", g * u, wo)
    eout = constrain(eout, "model", "data", None)

    # combine: gather each pair's expert output (OOB -> 0), weight by gate
    pair_out = eout.at[e_of_pair, pos_of_pair].get(mode="fill", fill_value=0)
    pair_gate = gates.reshape(-1, 1).astype(pair_out.dtype)
    out = jnp.zeros_like(xt).at[token_of_pair].add(pair_out * pair_gate)

    if cfg.n_shared_experts:
        sg = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xt.astype(jnp.float32), p["shared_gate"]))
        out = out + (mlp_apply(p["shared"], xt) * sg.astype(out.dtype))
    return out.reshape(lead + (d,))


def moe_ffn_shardmap(p, x, cfg, mesh):
    """Local-expert EP MoE under shard_map — the beyond-baseline dispatch
    (EXPERIMENTS.md §Perf, llama4 cell).

    Formulation: activations stay batch-sharded over DP and REPLICATED over
    the 'model' axis; each model-rank owns E/tp experts and locally selects +
    processes only the token-pairs routed to *its* experts; the partial
    outputs (disjoint token sets per rank) are combined with ONE psum over
    'model' — the same collective a dense TP FFN pays.  This removes the
    full-buffer all-reduces GSPMD emits for the scatter-based dispatch
    (observed ~10x collective-traffic reduction on llama4 train_4k).
    """
    from jax.sharding import PartitionSpec as PS

    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    E, k = cfg.n_experts, cfg.top_k
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    tpn = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
    el = E // tpn

    def local_ffn(xt_l, router, wg_l, wi_l, wo_l):
        t_loc = xt_l.shape[0]
        cap = capacity(t_loc, cfg)
        logits = jnp.einsum("td,de->te", xt_l.astype(jnp.float32), router)
        gates_all = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(gates_all, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
        m = jax.lax.axis_index("model") if tpn > 1 else 0
        lo = m * el
        # map global expert ids to local slots; foreign experts -> OOB drop
        e_flat = idx.reshape(-1)
        local_e = jnp.where((e_flat >= lo) & (e_flat < lo + el),
                            e_flat - lo, el)
        _, pos = dispatch_indices(local_e.reshape(-1, 1), el + 1, cap)
        token_of_pair = jnp.repeat(jnp.arange(t_loc), k)
        ebuf = jnp.zeros((el, cap, d), xt_l.dtype)
        ebuf = ebuf.at[local_e, pos].set(xt_l[token_of_pair], mode="drop")
        g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, wg_l))
        u = jnp.einsum("ecd,edf->ecf", ebuf, wi_l)
        eout = jnp.einsum("ecf,efd->ecd", g * u, wo_l)
        pair_out = eout.at[local_e, pos].get(mode="fill", fill_value=0)
        pair_gate = gates.reshape(-1, 1).astype(pair_out.dtype)
        out = jnp.zeros_like(xt_l).at[token_of_pair].add(pair_out * pair_gate)
        if tpn > 1:
            out = jax.lax.psum(out, "model")
        return out

    wspec = PS("model", None, None) if tpn > 1 else PS(None, None, None)
    xspec = PS(dp if dp else None, None)
    out = jax.shard_map(
        local_ffn, mesh=mesh,
        in_specs=(xspec, PS(None, None), wspec, wspec,
                  PS("model", None, None) if tpn > 1 else PS(None, None, None)),
        out_specs=xspec, check_vma=False,
    )(xt, p["router"], p["wg"], p["wi"], p["wo"])

    if cfg.n_shared_experts:
        sg = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xt.astype(jnp.float32), p["shared_gate"]))
        out = out + (mlp_apply(p["shared"], xt) * sg.astype(out.dtype))
    return out.reshape(lead + (d,))


def moe_ffn_dense_oracle(p, x, cfg):
    """O(T·E·d·f) oracle: run every expert on every token, combine by gates
    (no capacity drops).  Tests compare moe_ffn against this with a generous
    capacity factor so no token drops."""
    lead = x.shape[:-1]
    xt = x.reshape(-1, x.shape[-1])
    idx, gates = route(p, xt, cfg)
    g = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["wg"]))
    u = jnp.einsum("td,edf->tef", xt, p["wi"])
    alle = jnp.einsum("tef,efd->ted", g * u, p["wo"])      # (T,E,d)
    sel = jnp.take_along_axis(alle, idx[..., None], axis=1)  # (T,k,d)
    out = (sel * gates[..., None].astype(sel.dtype)).sum(axis=1)
    if cfg.n_shared_experts:
        sg = jax.nn.sigmoid(
            jnp.einsum("td,do->to", xt.astype(jnp.float32), p["shared_gate"]))
        out = out + (mlp_apply(p["shared"], xt) * sg.astype(out.dtype))
    return out.reshape(lead + (-1,))
