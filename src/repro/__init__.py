"""JAX reproduction of 'Design and Implementation of an Analysis Pipeline
for Heterogeneous Data': heterogeneous pilot runtime, distributed dataframe
operators, and the model/training substrate."""
import jax

if not hasattr(jax, "shard_map"):
    # jax < 0.5 ships shard_map under jax.experimental only and spells the
    # varying-manual-axes check `check_rep`; the codebase uses the stable
    # jax.shard_map spelling with `check_vma`.
    import functools

    from jax.experimental.shard_map import shard_map as _shard_map

    @functools.wraps(_shard_map)
    def _compat_shard_map(*args, **kwargs):
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _shard_map(*args, **kwargs)

    jax.shard_map = _compat_shard_map

if not hasattr(jax.sharding, "AxisType"):
    # jax < 0.6 has no sharding-in-types axis kinds; everything behaves as
    # Auto, so accept and drop the annotations.
    import enum
    import functools as _ft

    class _AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = _AxisType

    if hasattr(jax, "make_mesh"):   # absent before jax 0.4.35
        _make_mesh = jax.make_mesh

        @_ft.wraps(_make_mesh)
        def _compat_make_mesh(*args, **kwargs):
            kwargs.pop("axis_types", None)
            return _make_mesh(*args, **kwargs)

        jax.make_mesh = _compat_make_mesh

if not hasattr(jax.lax, "axis_size"):
    # jax < 0.5: psum of a literal 1 over a named axis is statically folded
    # to the axis size — the classic spelling of axis_size.
    jax.lax.axis_size = lambda name: jax.lax.psum(1, name)
