"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  Single pod: (16, 16) = 256 chips, axes
('data','model').  Multi-pod: (2, 16, 16) = 512 chips, axes
('pod','data','model') — the 'pod' axis crosses DCN.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices are available — used by
    tests, benches and the runtime's sub-mesh communicators."""
    n = len(jax.devices())
    assert data * model <= n, (data, model, n)
    devs = jax.devices()[: data * model]
    import numpy as np
    arr = np.array(devs).reshape(data, model)
    return jax.sharding.Mesh(arr, ("data", "model"))
