import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape × mesh) cell on
the production mesh with ShapeDtypeStruct inputs (zero allocation), record
memory_analysis / cost_analysis / per-collective traffic to JSON artifacts.

MUST be run as its own process (the XLA_FLAGS line above only works before
jax initializes devices):

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all          # spawns one subprocess per cell
"""
import argparse
import dataclasses
import json
import re
import subprocess
import sys
import time
from pathlib import Path

ART_DIR = Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", )
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|s32|u64|u32|s16|u16|s8|u8|pred)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^}]*\}|\[[^\]]*\]<=\[[^\]]*\](?:T\([^)]*\))?)")

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _parse_groups(spec: str, pod_size: int):
    """Returns (group_size, crosses_pod). Handles {{0,1},{2,3}} and iota
    [d0,d1]<=[s0,...]T(perm) formats exactly."""
    import numpy as np
    if spec.startswith("{"):
        groups = [[int(x) for x in g.split(",") if x.strip()]
                  for g in re.findall(r"\{([\d,\s]+)\}", spec)]
    else:
        m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", spec)
        dims = [int(x) for x in m.group(1).split(",")]
        src = [int(x) for x in m.group(2).split(",")]
        ids = np.arange(int(np.prod(src))).reshape(src)
        if m.group(3):
            ids = ids.transpose([int(x) for x in m.group(3).split(",")])
        groups = ids.reshape(dims).tolist()
        if len(dims) == 1:
            groups = [groups]
    gs = len(groups[0]) if groups else 1
    crosses = any(len({d // pod_size for d in g}) > 1 for g in groups)
    return gs, crosses


_TRAFFIC = {  # per-device link traffic as multiple of result bytes (ring algos)
    "all-reduce": lambda r, g: 2 * (g - 1) / g * r,
    "all-gather": lambda r, g: (g - 1) / g * r,
    "reduce-scatter": lambda r, g: (g - 1) * r,      # result is 1/g of input
    "all-to-all": lambda r, g: (g - 1) / g * r,
    "collective-permute": lambda r, g: r,
}


def parse_collectives(hlo_text: str, pod_size: int = 256):
    out = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m or "-done" in line.split("=")[0]:
            continue
        op = m.group("op")
        rbytes = _bytes_of(m.group("rtype"))
        gm = _GROUPS_RE.search(line)
        gs, dcn = _parse_groups(gm.group(1), pod_size) if gm else (1, False)
        traffic = _TRAFFIC[op](rbytes, max(gs, 1)) if gs > 1 else 0.0
        out.append({"op": op, "result_bytes": rbytes, "group_size": gs,
                    "traffic_bytes": traffic, "dcn": bool(dcn)})
    return out


def _group_size(cfg) -> int:
    """Layers per scan iteration (superblock / hybrid group)."""
    return cfg.shared_attn_period if cfg.family == "hybrid" else cfg.moe_layer_period


def _analysis_cfg(cfg, n_groups: int):
    """Tiny unrolled config for exact FLOP counting: cost_analysis counts scan
    bodies ONCE (verified), so we compile k=1 and k=2 fully-unrolled groups and
    extrapolate linearly — FLOPs/bytes/collectives are exactly linear in the
    number of groups."""
    kw = dict(n_layers=n_groups * _group_size(cfg), unroll_scans=True,
              ssm_chunk=2048)
    if cfg.n_encoder_layers:
        kw["n_encoder_layers"] = n_groups
    return dataclasses.replace(cfg, **kw)


def _measure(cfg, shape, mesh, parallel):
    """lower+compile; return (flops, bytes, collectives-by-op dict)."""
    from repro.distributed.steps import make_step
    bundle = make_step(cfg, mesh, parallel, shape)
    with mesh:
        compiled = bundle.fn.lower(*bundle.abstract_args).compile()
    cost = compiled.cost_analysis()
    colls = parse_collectives(compiled.as_text())
    by_op = {}
    for c in colls:
        k = c["op"] + ("_dcn" if c["dcn"] else "")
        d = by_op.setdefault(k, {"count": 0, "traffic_bytes": 0.0})
        d["count"] += 1
        d["traffic_bytes"] += c["traffic_bytes"]
    return (cost.get("flops", 0.0), cost.get("bytes accessed", 0.0), by_op)


def _extrapolate(f1, f2, n_groups: int):
    return f1 + (f2 - f1) * (n_groups - 1)


def analysis_pass(cfg, shape, mesh, parallel):
    """Exact per-device HLO FLOPs / bytes / collective traffic via two-point
    unrolled extrapolation.

    We fit on k=2 and k=3 groups (NOT k=1: single-group modules trigger
    different global GSPMD decisions around the logits head, observed
    empirically), then evaluate  f(G) = f2 + (f3 - f2) * (G - 2).
    """
    # big tiles keep unrolled-HLO small; with causal_skip we must keep the
    # runtime tile size so the skipped lower-triangle is visible in the HLO.
    blk = parallel.attn_block if cfg.causal_skip else 4096
    pa = dataclasses.replace(parallel, attn_block=blk)
    g_total = cfg.n_layers // _group_size(cfg)
    if g_total < 3:
        f = _measure(_analysis_cfg(cfg, g_total), shape, mesh, pa)
        return {"flops": f[0], "bytes": f[1], "collectives": f[2],
                "points": [f[0]]}

    def ev(a, b):
        return max(0.0, b + (b - a) * (g_total - 3))

    fl2, by2, c2 = _measure(_analysis_cfg(cfg, 2), shape, mesh, pa)
    fl3, by3, c3 = _measure(_analysis_cfg(cfg, 3), shape, mesh, pa)
    colls = {}
    for k in set(c2) | set(c3):
        a = c2.get(k, {"count": 0, "traffic_bytes": 0.0})
        b = c3.get(k, {"count": 0, "traffic_bytes": 0.0})
        colls[k] = {
            "count": round(ev(a["count"], b["count"])),
            "traffic_bytes": ev(a["traffic_bytes"], b["traffic_bytes"]),
        }
    return {
        "flops": ev(fl2, fl3),
        "bytes": ev(by2, by3),
        "collectives": colls,
        "points": [fl2, fl3],
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, parallel_overrides=None,
             out_path: Path | None = None, verbose: bool = True,
             analysis: bool | None = None, model_overrides=None):
    from repro.configs import ParallelConfig, get_config, get_shape, supports_shape
    from repro.distributed.steps import make_step
    from repro.launch.mesh import make_production_mesh

    cfg = get_config(arch)
    if model_overrides:
        cfg = dataclasses.replace(cfg, **model_overrides)
    shape = get_shape(shape_name)
    if not supports_shape(cfg, shape):
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                  "skipped": True,
                  "reason": f"{shape_name} requires sub-quadratic state; "
                            f"{cfg.family} arch is full-attention (DESIGN.md)"}
        if out_path:
            out_path.write_text(json.dumps(result, indent=1))
        return result

    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    parallel = ParallelConfig(**(parallel_overrides or {}))

    t0 = time.time()
    bundle = make_step(cfg, mesh, parallel, shape)
    with mesh:
        lowered = bundle.fn.lower(*bundle.abstract_args)
        t_lower = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    pod_size = 256
    colls = parse_collectives(text, pod_size)
    n_dev = mesh.devices.size

    ici = sum(c["traffic_bytes"] for c in colls if not c["dcn"])
    dcn = sum(c["traffic_bytes"] for c in colls if c["dcn"])
    by_op = {}
    for c in colls:
        k = c["op"] + ("_dcn" if c["dcn"] else "")
        d = by_op.setdefault(k, {"count": 0, "traffic_bytes": 0.0})
        d["count"] += 1
        d["traffic_bytes"] += c["traffic_bytes"]

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "n_devices": n_dev,
        "parallel": dataclasses.asdict(parallel),
        "model_overrides": model_overrides or {},
        "skipped": False,
        "flops_per_device": cost.get("flops", 0.0),
        "bytes_accessed_per_device": cost.get("bytes accessed", 0.0),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "collectives": {
            "per_op": by_op,
            "ici_traffic_bytes_per_device": ici,
            "dcn_traffic_bytes_per_device": dcn,
            "n_collective_ops": len(colls),
        },
        "model": {
            "params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
        },
        "timing": {"lower_s": t_lower, "compile_s": t_compile},
    }
    # exact per-layer-extrapolated analysis (roofline inputs) — single-pod only
    if analysis is None:
        analysis = not multi
    if analysis:
        t2 = time.time()
        result["analysis"] = analysis_pass(cfg, shape, mesh, parallel)
        ici_x = sum(v["traffic_bytes"]
                    for k, v in result["analysis"]["collectives"].items()
                    if not k.endswith("_dcn"))
        dcn_x = sum(v["traffic_bytes"]
                    for k, v in result["analysis"]["collectives"].items()
                    if k.endswith("_dcn"))
        result["analysis"]["ici_traffic_bytes_per_device"] = ici_x
        result["analysis"]["dcn_traffic_bytes_per_device"] = dcn_x
        result["timing"]["analysis_s"] = time.time() - t2
    if out_path:
        out_path.parent.mkdir(parents=True, exist_ok=True)
        out_path.write_text(json.dumps(result, indent=1))
    if verbose:
        print(json.dumps({k: result[k] for k in
                          ("arch", "shape", "mesh", "flops_per_device",
                           "bytes_accessed_per_device")}, indent=1))
        print("memory:", result["memory"])
        print("collectives:", result["collectives"]["per_op"])
    return result


def _jit_kwargs(bundle):  # pragma: no cover - placeholder for symmetry
    return {}


def cell_path(arch, shape, mesh_kind, tag="baseline"):
    return ART_DIR / f"{arch}__{shape}__{mesh_kind}__{tag}.json"


_CELL_ORDER = [  # cheap/dense first so most of the table lands early;
                 # SSM/hybrid (slowest XLA:CPU compiles) last
    "internvl2-1b", "whisper-medium", "qwen3-8b", "codeqwen1.5-7b",
    "granite-3-8b", "minitron-8b", "qwen2-moe-a2.7b",
    "llama4-maverick-400b-a17b", "falcon-mamba-7b", "zamba2-7b",
]


def all_cells():
    from repro.configs import SHAPES
    for arch in _CELL_ORDER:
        for shape in SHAPES:
            for mesh_kind in ("single", "multi"):
                yield arch, shape, mesh_kind


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--parallel", default=None,
                    help="JSON dict of ParallelConfig overrides")
    ap.add_argument("--model", default=None,
                    help="JSON dict of ModelConfig overrides (perf knobs)")
    ap.add_argument("--timeout", type=int, default=1800)
    args = ap.parse_args()
    overrides = json.loads(args.parallel) if args.parallel else None
    m_overrides = json.loads(args.model) if args.model else None

    if args.all:
        failures = []
        for arch, shape, mesh_kind in all_cells():
            out = cell_path(arch, shape, mesh_kind, args.tag)
            if out.exists() and not args.force:
                print(f"skip (cached): {out.name}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                   "--tag", args.tag]
            if args.parallel:
                cmd += ["--parallel", args.parallel]
            print(f"=== {arch} × {shape} × {mesh_kind}", flush=True)
            try:
                r = subprocess.run(cmd, timeout=args.timeout)
                if r.returncode != 0:
                    failures.append((arch, shape, mesh_kind))
            except subprocess.TimeoutExpired:
                failures.append((arch, shape, mesh_kind, "timeout"))
        if failures:
            print("FAILED CELLS:", failures)
            sys.exit(1)
        print("all cells OK")
        return

    out = cell_path(args.arch, args.shape, args.mesh, args.tag)
    run_cell(args.arch, args.shape, args.mesh, overrides, out,
             model_overrides=m_overrides)


if __name__ == "__main__":
    main()
