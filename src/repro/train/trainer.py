"""Training loop: step bundle + data + checkpointing + fault recovery.

Used at toy scale by the examples/tests on the local mesh; the SAME step
builders lower onto the 256/512-chip production meshes in the dry-run.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterable, Optional

import jax

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig
from repro.distributed import sharding as sh
from repro.distributed.steps import make_train_step
from repro.models import registry
from repro.train import checkpoint as ckpt
from repro.train import optimizer as opt_mod


@dataclasses.dataclass
class TrainState:
    params: object
    opt_state: object
    step: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, mesh, parallel: ParallelConfig,
                 shape: ShapeConfig, ocfg: Optional[opt_mod.OptimizerConfig] = None,
                 ckpt_dir: Optional[str] = None, ckpt_every: int = 0,
                 seed: int = 0):
        self.cfg = cfg
        self.mesh = mesh
        self.parallel = parallel
        self.shape = shape
        self.ocfg = ocfg or opt_mod.OptimizerConfig()
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.bundle = make_train_step(cfg, mesh, parallel, shape, self.ocfg)
        self.api = registry.get_model(cfg)
        self._seed = seed

    def init_state(self) -> TrainState:
        pspecs = self.bundle.info["pspecs"]
        with self.mesh:
            init = jax.jit(
                lambda k: self.api.init(k, self.cfg),
                out_shardings=sh.named(self.mesh, pspecs))
            params = init(jax.random.key(self._seed))
            opt_state = jax.jit(
                opt_mod.adamw_init,
                out_shardings=sh.named(self.mesh, sh.opt_specs(None, pspecs)))(params)
        return TrainState(params=params, opt_state=opt_state, step=0)

    def maybe_restore(self) -> Optional[TrainState]:
        if not self.ckpt_dir:
            return None
        step = ckpt.latest_step(self.ckpt_dir)
        if step is None:
            return None
        params_shape = registry.eval_params_shape(self.cfg)
        opt_shape = jax.eval_shape(opt_mod.adamw_init, params_shape)
        pspecs = self.bundle.info["pspecs"]
        like = {"params": params_shape, "opt": opt_shape}
        specs = {"params": pspecs, "opt": sh.opt_specs(None, pspecs)}
        tree = ckpt.restore(self.ckpt_dir, step, like, mesh=self.mesh,
                            specs=specs)
        return TrainState(params=tree["params"], opt_state=tree["opt"],
                          step=step)

    def fit(self, batches: Iterable[dict], steps: int,
            state: Optional[TrainState] = None,
            log_every: int = 10,
            on_metrics: Optional[Callable[[int, dict], None]] = None):
        state = state or self.init_state()
        losses = []
        pending_save = None
        t0 = time.monotonic()  # rate measurement must not jump under NTP
        with self.mesh:
            for i, batch in enumerate(batches):
                if i >= steps:
                    break
                jb = {k: jax.numpy.asarray(v) for k, v in batch.items()}
                state.params, state.opt_state, metrics = self.bundle.fn(
                    state.params, state.opt_state, jb)
                state.step += 1
                loss = float(metrics["loss"])
                losses.append(loss)
                if on_metrics:
                    on_metrics(state.step, {k: float(v) for k, v in metrics.items()})
                if log_every and state.step % log_every == 0:
                    rate = state.step / max(time.monotonic() - t0, 1e-9)
                    print(f"step {state.step:5d}  loss {loss:.4f}  "
                          f"lr {float(metrics['lr']):.2e}  {rate:.2f} it/s",
                          flush=True)
                if self.ckpt_dir and self.ckpt_every and \
                        state.step % self.ckpt_every == 0:
                    if pending_save is not None:
                        pending_save.join()
                    pending_save = ckpt.save(
                        self.ckpt_dir, state.step,
                        {"params": state.params, "opt": state.opt_state})
        if pending_save is not None:
            pending_save.join()
        return state, losses
