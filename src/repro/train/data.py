"""Data pipeline: the 'analysis pipeline feeding DL' story of the paper.

Two sources:
  * SyntheticCorpus — deterministic zipf-ish token stream (tests, smoke).
  * etl_token_batches — runs a real dataframe pipeline (filter -> hash join
    -> groupby dedup -> sample-sort) via the runtime's dataframe engine and
    yields training batches from the resulting token column, demonstrating
    ETL -> training handoff inside one framework (examples/train_lm.py).
"""
from __future__ import annotations

import numpy as np


class SyntheticCorpus:
    """Deterministic pseudo-corpus with skewed unigram stats + local
    structure (next token correlates with previous), so tiny LMs show a
    decreasing loss."""

    def __init__(self, vocab: int, seed: int = 0):
        self.vocab = vocab
        self.rng = np.random.default_rng(seed)
        # zipf-ish unigram distribution
        ranks = np.arange(1, vocab + 1)
        self.p = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, batch: int, seq: int) -> dict:
        base = self.rng.choice(self.vocab, size=(batch, seq), p=self.p)
        # inject bigram structure: with prob .5, token = prev + 1 (mod V)
        copy = self.rng.random((batch, seq)) < 0.5
        shifted = np.roll(base, 1, axis=1) + 1
        tokens = np.where(copy, shifted % self.vocab, base).astype(np.int32)
        return {"tokens": tokens, "labels": tokens}

    def batches(self, batch: int, seq: int, steps: int):
        for _ in range(steps):
            yield self.batch(batch, seq)


def make_events(n_rows: int, vocab: int, seed: int = 0) -> dict:
    """Raw 'event log' the ETL pipeline cleans: (event_id, doc_id, token,
    quality) rows — heterogeneous analytics input."""
    rng = np.random.default_rng(seed)
    return {
        "event_id": np.arange(n_rows, dtype=np.int32),
        "doc_id": rng.integers(0, max(n_rows // 64, 4), n_rows, dtype=np.int32),
        "token": rng.integers(0, vocab, n_rows, dtype=np.int32),
        "quality": rng.random(n_rows).astype(np.float32),
    }


def etl_token_batches(comm, events: dict, doc_meta: dict, *, batch: int,
                      seq: int, capacity_per_rank: int = 8192):
    """Run the cleaning pipeline on the communicator's mesh and yield batches.

    Pipeline (all distributed dataframe ops):
      1. filter: drop rows with quality < 0.2
      2. hash-join events with doc metadata on doc_id (adds doc weight)
      3. sample-sort by (doc_id) so documents are contiguous
      4. emit the token column as (batch, seq) training blocks
    """
    from repro.dataframe import ops_dist as D
    from repro.dataframe import ops_local as L
    from repro.dataframe.table import Table

    t = D.shard_table(comm, events, capacity_per_rank)
    meta = D.shard_table(comm, doc_meta, max(len(doc_meta["doc_id"]) //
                                             comm.size + 8, 64))

    # 1. local filter (quality)
    from functools import partial
    import jax
    from jax.sharding import PartitionSpec as P

    @partial(jax.shard_map, mesh=comm.mesh, in_specs=(P("df"),),
             out_specs=P("df"), check_vma=False)
    def _filter(tab):
        out = L.filter_rows(tab, tab.columns["quality"] >= 0.2)
        return Table(columns=out.columns, nrows=out.nrows.reshape(1))

    t = _filter(t)
    # 2. distributed join with metadata
    join = D.make_dist_join(comm.mesh, "doc_id", out_factor=4.0)
    t, ovf = join(t, meta)
    # 3. distributed sort by doc_id
    srt = D.make_dist_sort(comm.mesh, "doc_id")
    t, ovf2 = srt(t)
    tokens = D.collect_table(t)["token"]

    n_blocks = len(tokens) // (batch * seq)
    for i in range(n_blocks):
        blk = tokens[i * batch * seq:(i + 1) * batch * seq]
        arr = blk.reshape(batch, seq).astype(np.int32)
        yield {"tokens": arr, "labels": arr}
