"""Sharded checkpointing with async save and ELASTIC restore.

Layout:  <dir>/step_<N>/
           manifest.json           — tree structure, shapes, dtypes, step
           <leaf-path>.npy         — one file per pytree leaf

Saves run on a background thread (training continues).  Restore takes a
target mesh + specs and ``jax.device_put``s each leaf with its NamedSharding —
so a checkpoint written on one mesh restores onto ANY mesh shape (elastic
re-shard at load), which is the recovery path after pool shrink/grow.
"""
from __future__ import annotations

import json
import threading
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, jax.tree.structure(tree)


def save(ckpt_dir, step: int, tree, *, async_: bool = True):
    """Write the pytree; returns a join()-able handle."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    flat, _ = _flatten(tree)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        manifest = {"step": step, "leaves": {}}
        for k, v in host.items():
            fname = k.replace("/", "__") + ".npy"
            np.save(d / fname, v)
            manifest["leaves"][k] = {"file": fname, "shape": list(v.shape),
                                     "dtype": str(v.dtype)}
        (d / "manifest.json").write_text(json.dumps(manifest))
        (Path(ckpt_dir) / "LATEST").write_text(str(step))

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir) -> int | None:
    f = Path(ckpt_dir) / "LATEST"
    if not f.exists():
        return None
    return int(f.read_text().strip())


def restore(ckpt_dir, step: int, like, *, mesh=None, specs=None):
    """Load into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With mesh+specs, each leaf is device_put with its
    NamedSharding — restoring onto a different mesh re-shards transparently."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_like, _ = _flatten(like)
    flat_specs, _ = _flatten(specs) if specs is not None else ({}, None)

    loaded = {}
    for k, meta in manifest["leaves"].items():
        arr = np.load(d / meta["file"])
        want = flat_like.get(k)
        if want is not None:
            arr = arr.astype(want.dtype)
        if mesh is not None and k in flat_specs:
            arr = jax.device_put(arr, NamedSharding(mesh, flat_specs[k]))
        loaded[k] = arr

    # rebuild via the same key order as `like`
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        vals.append(loaded[key])
    return jax.tree_util.tree_unflatten(treedef, vals)
