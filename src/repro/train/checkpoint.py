"""Sharded checkpointing with async save and ELASTIC restore.

Layout:  <dir>/step_<N>/
           manifest.json           — tree structure, shapes, dtypes, step
           <leaf-path>.npy         — one file per pytree leaf

Saves run on a background thread (training continues).  Restore takes a
target mesh + specs and ``jax.device_put``s each leaf with its NamedSharding —
so a checkpoint written on one mesh restores onto ANY mesh shape (elastic
re-shard at load), which is the recovery path after pool shrink/grow.

Crash-safety contract: a step EXISTS iff its ``manifest.json`` landed
complete — leaf files are written first, then the manifest commits the step
via tmp-file + ``os.replace``, and only then does ``LATEST`` advance (also
atomically, and only forward).  A process killed mid-save therefore leaves
either a fully restorable step or an ignorable partial dir; ``latest_step``
validates what ``LATEST`` points at and falls back to the newest step whose
manifest is complete, so a torn tail never wedges resume.

``CheckpointContext`` is the task-level face of this module: the runtime
binds one per ``(task lineage, attempt, part)`` and hands it to payloads as
``comm.checkpoint`` — each attempt writes only into its own directory, but
``latest()``/``restore()`` read across sibling attempts, so a retry or a
speculative twin resumes from whatever step the doomed primary durably
completed.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path

import numpy as np

# jax is imported lazily inside functions — importing this module (e.g. in a
# pilot worker building a CheckpointContext) stays cheap on its own.

STEP_FMT = "step_{:08d}"

# serializes LATEST read-modify-write within a process; cross-process safety
# comes from the runtime binding one writer (uid, attempt, part) per dir
_latest_lock = threading.Lock()


class CheckpointError(RuntimeError):
    """Structured checkpoint failure (missing leaf, no restorable step...)."""


def _flatten(tree):
    import jax
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, jax.tree.structure(tree)


# Plain trees — dict/list/tuple containers over numpy/scalar leaves — are
# handled without jax at all, producing the SAME leaf keys as the jax
# flatten (path parts joined by "/"), so the two paths read each other's
# checkpoints and a task checkpointing plain numpy state never touches the
# JAX tree machinery on its hot save path.

def _is_plain(tree) -> bool:
    if isinstance(tree, dict):
        return all(_is_plain(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return all(_is_plain(v) for v in tree)
    return isinstance(tree, (np.ndarray, np.generic, bool, int, float,
                             complex))


def _flatten_plain(tree, path=(), out=None) -> dict:
    out = {} if out is None else out
    if isinstance(tree, dict):
        for k, v in tree.items():
            _flatten_plain(v, path + (str(k),), out)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            _flatten_plain(v, path + (str(i),), out)
    else:
        out["/".join(path)] = tree
    return out


def _rebuild_plain(like, loaded: dict, ctx: str, path=()):
    if isinstance(like, dict):
        return {k: _rebuild_plain(v, loaded, ctx, path + (str(k),))
                for k, v in like.items()}
    if isinstance(like, (list, tuple)):
        vals = [_rebuild_plain(v, loaded, ctx, path + (str(i),))
                for i, v in enumerate(like)]
        if hasattr(like, "_fields"):          # namedtuple
            return type(like)(*vals)
        return type(like)(vals)
    key = "/".join(path)
    if key not in loaded:
        raise CheckpointError(
            f"{ctx} has no leaf {key!r} required by `like`; "
            f"checkpoint holds {sorted(loaded)}")
    return loaded[key]


def _atomic_write_text(path: Path, text: str) -> None:
    tmp = path.with_name(f".{path.name}.tmp.{os.getpid()}.{threading.get_ident()}")
    tmp.write_text(text)
    os.replace(tmp, path)


def _advance_latest(root: Path, step: int) -> None:
    with _latest_lock:
        cur = _read_latest(root)
        if cur is None or step > cur:
            _atomic_write_text(root / "LATEST", str(step))


def _read_latest(root: Path) -> int | None:
    try:
        return int((root / "LATEST").read_text().strip())
    except (OSError, ValueError):
        return None  # absent or torn — caller falls back to manifest scan


def _manifest_ok(d: Path, step: int | None = None) -> dict | None:
    """The step's manifest, or None unless it parses, matches ``step``, and
    every leaf file it names is present (= the step committed completely)."""
    try:
        m = json.loads((d / "manifest.json").read_text())
    except (OSError, ValueError):
        return None
    if not isinstance(m, dict) or not isinstance(m.get("leaves"), dict):
        return None
    if step is not None and m.get("step") != step:
        return None
    for meta in m["leaves"].values():
        if not (d / meta["file"]).exists():
            return None
    return m


def save(ckpt_dir, step: int, tree, *, async_: bool = True):
    """Write the pytree; returns a join()-able handle."""
    d = Path(ckpt_dir) / STEP_FMT.format(step)
    d.mkdir(parents=True, exist_ok=True)
    if _is_plain(tree):
        host = {k: np.asarray(v) for k, v in _flatten_plain(tree).items()}
    else:
        import jax
        flat, _ = _flatten(tree)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

    def _write():
        manifest = {"step": step, "leaves": {}}
        for k, v in host.items():
            fname = k.replace("/", "__") + ".npy"
            np.save(d / fname, v)
            manifest["leaves"][k] = {"file": fname, "shape": list(v.shape),
                                     "dtype": str(v.dtype)}
        # commit point: the step exists once the manifest lands whole
        _atomic_write_text(d / "manifest.json", json.dumps(manifest))
        _advance_latest(Path(ckpt_dir), step)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def completed_steps(ckpt_dir) -> list[int]:
    """Ascending steps under ``ckpt_dir`` whose manifests are complete."""
    root = Path(ckpt_dir)
    steps = []
    try:
        entries = list(root.iterdir())
    except OSError:
        return []
    for d in entries:
        if not d.name.startswith("step_"):
            continue
        try:
            s = int(d.name.split("_", 1)[1])
        except ValueError:
            continue
        if _manifest_ok(d, s) is not None:
            steps.append(s)
    return sorted(steps)


def latest_step(ckpt_dir) -> int | None:
    root = Path(ckpt_dir)
    cur = _read_latest(root)
    if cur is not None and _manifest_ok(root / STEP_FMT.format(cur), cur) is not None:
        return cur
    # LATEST absent/torn/pointing at an incomplete step: trust the manifests
    steps = completed_steps(root)
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, like, *, mesh=None, specs=None):
    """Load into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  With mesh+specs, each leaf is device_put with its
    NamedSharding — restoring onto a different mesh re-shards transparently."""
    d = Path(ckpt_dir) / STEP_FMT.format(step)
    manifest = _manifest_ok(d, step)
    if manifest is None:
        raise CheckpointError(
            f"no complete checkpoint for step {step} under {ckpt_dir}")
    plain = mesh is None and specs is None and _is_plain(like)
    if plain:
        flat_like, flat_specs = _flatten_plain(like), {}
    else:
        flat_like, _ = _flatten(like)
        flat_specs, _ = _flatten(specs) if specs is not None else ({}, None)

    loaded = {}
    for k, meta in manifest["leaves"].items():
        arr = np.load(d / meta["file"])
        want_dt = getattr(flat_like.get(k), "dtype", None)
        if want_dt is not None and arr.dtype != np.dtype(want_dt):
            arr = arr.astype(want_dt)      # no-op dtypes skip the copy
        if mesh is not None and k in flat_specs:
            import jax
            from jax.sharding import NamedSharding
            arr = jax.device_put(arr, NamedSharding(mesh, flat_specs[k]))
        loaded[k] = arr

    # rebuild via the same key order as `like`
    if plain:
        return _rebuild_plain(like, loaded, f"step {step} at {d}")
    import jax
    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    vals = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in loaded:
            raise CheckpointError(
                f"step {step} at {d} has no leaf {key!r} required by `like`; "
                f"manifest holds {sorted(manifest['leaves'])}")
        vals.append(loaded[key])
    return jax.tree_util.tree_unflatten(treedef, vals)


class CheckpointContext:
    """Task-level checkpoint handle, bound per ``(task lineage, attempt, part)``.

    Directory layout under the session checkpoint root::

        <root>/t<primary-uid>/p<part>-of-<n_parts>/<attempt>/step_<N>/...

    ``save`` writes only into this attempt's own directory (no cross-attempt
    write races — a doomed primary keeps appending steps while its retry is
    already up).  ``latest``/``restore`` read the whole part scope: own
    attempt first, then sibling attempts newest-step-first, which is how a
    retry (attempt ``a1``) or a speculative twin (attempt ``s<uid>``) picks
    up the primary ``a0``'s last durably completed step.  A task relaunched
    with a different part split gets a different scope and conservatively
    starts fresh.  ``resumed_from_step`` records the last step restored and
    flows back through PART_DONE → ExecEvent → TraceEvent as resume evidence.
    """

    def __init__(self, task_dir, *, attempt: str = "a0",
                 part: int = 0, n_parts: int = 1):
        self.attempt = str(attempt) or "a0"
        self.scope = Path(task_dir) / f"p{part}-of-{n_parts}"
        self.dir = self.scope / self.attempt       # this attempt's write dir
        self.resumed_from_step = 0

    def _read_dirs(self) -> list[Path]:
        try:
            siblings = [d for d in self.scope.iterdir()
                        if d.is_dir() and d != self.dir]
        except OSError:
            siblings = []
        ranked = sorted(siblings,
                        key=lambda d: latest_step(d) if latest_step(d) is not None
                        else -1, reverse=True)
        return [self.dir] + ranked

    def save(self, step: int, tree, *, async_: bool = False):
        """Durable by default: payloads report a step done only once it is
        restorable (pass ``async_=True`` to overlap with compute)."""
        return save(self.dir, step, tree, async_=async_)

    def latest(self) -> int | None:
        steps = [s for d in self._read_dirs()
                 if (s := latest_step(d)) is not None]
        return max(steps) if steps else None

    def restore(self, step: int, like, *, mesh=None, specs=None):
        last_err = None
        for d in self._read_dirs():
            if _manifest_ok(d / STEP_FMT.format(step), step) is None:
                continue
            try:
                tree = restore(d, step, like, mesh=mesh, specs=specs)
            except CheckpointError as e:
                last_err = e
                continue
            self.resumed_from_step = max(self.resumed_from_step, step)
            return tree
        raise last_err or CheckpointError(
            f"no attempt under {self.scope} holds a complete step {step}")
