"""AdamW + cosine schedule + global-norm clipping, written directly on
pytrees (optax is not available in this container).  Moments are kept in f32
regardless of param dtype; the returned optimizer state shards exactly like
the params (see distributed/sharding.py).
"""
from __future__ import annotations

import dataclasses
import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    peak_lr: float = 3e-4
    min_lr_ratio: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def cosine_schedule(cfg: OptimizerConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    mn = cfg.peak_lr * cfg.min_lr_ratio
    cos = mn + 0.5 * (cfg.peak_lr - mn) * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params):
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros32, params),
        "nu": jax.tree.map(zeros32, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def _is_matrix(p) -> bool:
    # weight decay only on >=2D weights (skip norms/biases/scalars)
    return p.ndim >= 2


def adamw_update(grads, opt_state, params, cfg: OptimizerConfig):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    lr = cosine_schedule(cfg, count)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def upd(g, mu, nu, p):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu / (1 - cfg.b1 ** count.astype(jnp.float32))
        nu_hat = nu / (1 - cfg.b2 ** count.astype(jnp.float32))
        step = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if _is_matrix(p):
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * step).astype(p.dtype)
        return newp, mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(g, m, n, p) for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
