"""Flight recorder: worker-side span tracing, telemetry-carrying
heartbeats, durable JSONL trace capture with Perfetto export, and replay
loading into the virtual clock.

The subsystem spans four layers with one schema:

* ``spans``   — the per-part timing API workers instrument task execution
  with (launch_recv / deserialize / comm_build / compute / p2p_send /
  p2p_recv / spill_write / merge), shipped back on PART_DONE and aligned
  into the parent clock via the HELLO handshake offset.
* ``metrics`` — the counter/gauge registry whose snapshot rides every
  HEARTBEAT frame (queue depth, RSS, spill bytes, peer channels,
  p2p_fallbacks), surfacing as ``telemetry`` trace events.
* ``trace``   — ``TraceWriter`` (crash-safe line-buffered JSONL via
  ``REPRO_TRACE`` / ``SchedulerSession(trace_path=)``), ``load_trace``,
  and replay through ``VirtualClockExecutor``.
* ``perfetto`` — Chrome/Perfetto ``trace.json`` export with one row per
  worker/device lane plus counter tracks
  (``python -m repro.obs.perfetto run.jsonl``).
"""
from repro.obs.metrics import MetricsRegistry, rss_mb
from repro.obs.perfetto import export_perfetto
from repro.obs.spans import (NullRecorder, SpanRecorder, align, bound,
                             current_recorder, set_current)
from repro.obs.trace import (RecordedTrace, TraceWriter, load_trace,
                             resolve_trace_path)

__all__ = [
    "MetricsRegistry", "NullRecorder", "RecordedTrace", "SpanRecorder",
    "TraceWriter", "align", "bound", "current_recorder", "export_perfetto",
    "load_trace", "resolve_trace_path", "rss_mb", "set_current",
]
