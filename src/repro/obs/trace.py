"""Durable trace capture and replay loading.

:class:`TraceWriter` streams every scheduler :class:`TraceEvent`, every
worker span, and every telemetry snapshot to a JSONL file as they happen —
one JSON object per line, line-buffered, so a SIGKILLed run still yields a
readable prefix (the crash-forensics contract).  The schema is identical on
all three executor backends; sim/thread runs simply contain no span or
telemetry lines.

Line types::

  {"type": "meta",      "n_devices": 4, "backend": "proc", ...}
  {"type": "event",     "t": ..., "kind": "dispatch", "task": ..., ...}
  {"type": "span",      "kind": "compute", "t0": ..., "t1": ...,
                        "worker": "w0", "part": 0, "uid": 7, "task": ...}
  {"type": "telemetry", "t": ..., "worker": "w0", "queue_depth": 1, ...}

:func:`load_trace` is the inverse: it reconstructs the run as a
:class:`RecordedTrace` whose ``.trace``/``.tasks`` quack enough like a
``SimReport`` that ``benchmarks.common.trace_summary`` reports identical
counters, and whose :meth:`RecordedTrace.replay` re-runs the recorded
arrival/duration skeleton through ``VirtualClockExecutor`` — the first
concrete step of the ROADMAP's trace-replay policy-zoo item (record live,
score candidate policies offline on the virtual clock).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Optional


def resolve_trace_path(trace_path=None) -> Optional[str]:
    """Where this session's JSONL goes.  Explicit ``trace_path`` wins; else
    the ``REPRO_TRACE`` env knob.  A value naming a *directory* (existing,
    or spelled with a trailing separator) gets one unique file per session —
    that is what lets CI export ``REPRO_TRACE`` once for a whole test job
    without sessions clobbering each other."""
    path = trace_path or os.environ.get("REPRO_TRACE")
    if not path:
        return None
    path = str(path)
    if path.endswith(os.sep) or os.path.isdir(path):
        os.makedirs(path, exist_ok=True)
        n = 0
        while True:
            cand = os.path.join(path, f"trace-{os.getpid()}-{n}.jsonl")
            if not os.path.exists(cand):
                return cand
            n += 1
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    return path


class TraceWriter:
    """Streams trace lines to ``path``; every line is flushed as written
    (text mode, ``buffering=1``) so the file is a valid prefix at any
    instant — a reader tolerates at most one torn final line."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "w", buffering=1, encoding="utf-8")

    def _line(self, obj: dict):
        try:
            self._f.write(json.dumps(obj, default=str) + "\n")
        except ValueError:
            pass                      # writer closed mid-teardown: drop

    def meta(self, **fields):
        self._line({"type": "meta", **fields})

    def event(self, ev):
        self._line({"type": "event", **ev.asdict()})

    def span(self, span: dict):
        self._line({"type": "span", **span})

    def telemetry(self, rec: dict):
        self._line({"type": "telemetry", **rec})

    def close(self):
        try:
            self._f.close()
        except OSError:
            pass


@dataclasses.dataclass
class _TaskStub:
    """Per-task counters reconstructed from terminal trace events — just
    enough surface for ``trace_summary``'s per-task sums."""
    name: str
    uid: int
    hub_calls: int = 0
    spills: int = 0
    p2p_fallbacks: int = 0
    hub_relay_bytes: int = 0
    raw_coll_bytes: int = 0
    shm_bytes: int = 0
    ring_steps: int = 0
    resumed_from_step: int = 0


@dataclasses.dataclass
class RecordedTrace:
    """A loaded JSONL trace, shaped like the slice of ``SimReport`` the
    trace consumers need (``.trace`` of TraceEvents, ``.tasks`` stubs,
    ``.spans``, plus the recorded telemetry stream and meta header)."""
    meta: dict
    trace: list
    spans: list
    telemetry: list
    tasks: list

    def events(self, kind: Optional[str] = None) -> list:
        if kind is None:
            return list(self.trace)
        return [e for e in self.trace if e.kind == kind]

    # -- replay ------------------------------------------------------------
    def replay_descs(self):
        """The recorded run's arrival/duration skeleton as (descs,
        n_devices): one TaskDescription per recorded uid, in submit order,
        with the measured dispatch->terminal duration as its virtual-clock
        ``duration_model`` and the recorded ranks/pipeline/priority-free
        tags.  Tasks that never reached a terminal event (crash-truncated
        trace) replay with zero duration — they still count a submit and a
        dispatch, which is what a schedule-shape comparison needs."""
        from repro.core.task import TaskDescription

        dispatch: dict = {}
        duration: dict = {}
        order: list = []
        info: dict = {}
        for e in self.trace:
            if e.kind == "submit" and e.uid not in info:
                order.append(e.uid)
                info[e.uid] = e
            elif e.kind == "dispatch":
                dispatch[e.uid] = e.t
            elif e.kind in ("done", "fail") and e.uid in dispatch:
                duration[e.uid] = max(e.t - dispatch[e.uid], 0.0)
        descs = []
        for uid in order:
            e = info[uid]
            dur = duration.get(uid, 0.0)
            descs.append(TaskDescription(
                name=e.task, ranks=max(e.ranks, 1), fn=None,
                duration_model=(lambda r, d=dur: d),
                tags={"pipeline": e.pipeline or "default"}))
        n_devices = int(self.meta.get("n_devices") or 0)
        if n_devices <= 0:
            n_devices = max((d.ranks for d in descs), default=1)
        return descs, n_devices

    def replay(self, opts=None):
        """Re-run the skeleton through ``VirtualClockExecutor`` and return
        its ``SimReport``: for a clean recorded run, ``trace_summary`` of
        the replay matches the live run's n_submit/n_dispatch/n_done
        exactly (same tasks, same pool size, noise-free durations)."""
        from repro.core.executors import SimOptions
        from repro.core.scheduler import simulate

        descs, n_devices = self.replay_descs()
        opts = opts or SimOptions(
            noise=0.0, overhead_model=lambda r: 0.0,
            placement=self.meta.get("placement", "spread"))
        return simulate(descs, n_devices, opts)


def load_trace(path: str) -> RecordedTrace:
    """Parse a JSONL trace back into a :class:`RecordedTrace`.  A torn final
    line (SIGKILL mid-write) is skipped, not fatal — every complete line of
    a crashed run stays loadable."""
    from repro.core.scheduler import TraceEvent

    meta: dict = {}
    trace: list = []
    spans: list = []
    telemetry: list = []
    stubs: dict = {}
    fields = {f.name for f in dataclasses.fields(TraceEvent)}
    with open(path, encoding="utf-8") as f:
        for line in f:
            try:
                obj = json.loads(line)
            except json.JSONDecodeError:
                continue              # torn tail of a killed run
            typ = obj.pop("type", None)
            if typ == "meta":
                meta.update(obj)
            elif typ == "event":
                ev = TraceEvent(**{k: v for k, v in obj.items()
                                   if k in fields})
                trace.append(ev)
                if ev.kind in ("done", "fail") and ev.uid >= 0:
                    d = ev.data or {}
                    stubs[ev.uid] = _TaskStub(
                        name=ev.task, uid=ev.uid,
                        hub_calls=int(d.get("hub_calls", 0)),
                        spills=int(ev.spills),
                        p2p_fallbacks=int(d.get("p2p_fallbacks", 0)),
                        hub_relay_bytes=int(d.get("hub_relay_bytes", 0)),
                        raw_coll_bytes=int(d.get("raw_coll_bytes", 0)),
                        shm_bytes=int(d.get("shm_bytes", 0)),
                        ring_steps=int(d.get("ring_steps", 0)),
                        resumed_from_step=int(d.get("resumed_from_step", 0)))
            elif typ == "span":
                spans.append(obj)
            elif typ == "telemetry":
                telemetry.append(obj)
    return RecordedTrace(meta=meta, trace=trace, spans=spans,
                         telemetry=telemetry, tasks=list(stubs.values()))
