"""Worker-side span tracing: the lightweight timing API the flight recorder
instruments task execution with.

A *span* is one timed section of a task part — ``launch_recv``,
``deserialize``, ``comm_build``, ``compute``, ``p2p_send``, ``p2p_recv``,
``spill_write``, ``merge`` — recorded as ``(kind, t0, t1)`` in the worker's
``perf_counter`` clock.  :class:`SpanRecorder` collects them with near-zero
overhead (two clock reads and a list append per span; no locks on the hot
path beyond a plain list, which is append-safe under the GIL), ships them
back piggybacked on the PART_DONE frame, and the parent aligns them into its
own clock with the per-worker offset established during the HELLO handshake
(see ``executors/proc.py``).

Deeply-nested code (``shuffle.SpillBuffer`` spilling inside a payload) does
not thread a recorder through every call: the worker binds the part's
recorder to the *thread* running the payload (:func:`set_current` /
:func:`current_recorder`), and un-instrumented contexts get a no-op recorder
— sim/thread backends produce empty span sections, never schema drift.
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from time import perf_counter

#: span kinds the worker emits (documentation + the Perfetto exporter's
#: compute-vs-wait classification; recorders accept any string)
SPAN_KINDS = (
    "launch_recv",    # LAUNCH frame received -> part thread picked it up
    "deserialize",    # cloudpickle loads of the task payload
    "comm_build",     # local sub-mesh communicator construction
    "compute",        # the payload function itself
    "p2p_send",       # writing a peer-data frame to a peer channel
    "p2p_recv",       # waiting for a peer frame / hub collective result
    "spill_write",    # writing a spilled shuffle run to disk
    "merge",          # streaming k-way merge of spilled runs
)

#: span kinds that are *waits* (time the part was blocked on someone else),
#: as opposed to local work — the compute-vs-wait shading in trace_gantt and
#: the ``comm_wait_s`` breakdown in trace_summary
WAIT_KINDS = frozenset({"p2p_recv"})


class SpanRecorder:
    """Collects ``(kind, t0, t1)`` spans on the local ``perf_counter`` clock.

    ``span`` is the context-manager form; ``add`` records a finished span
    directly (for callers that already hold both timestamps).  ``export``
    returns plain tuples ready for a wire frame.
    """

    __slots__ = ("spans",)

    def __init__(self):
        self.spans: list[tuple] = []

    def add(self, kind: str, t0: float, t1: float):
        self.spans.append((kind, t0, t1))

    @contextmanager
    def span(self, kind: str):
        t0 = perf_counter()
        try:
            yield
        finally:
            self.spans.append((kind, t0, perf_counter()))

    def export(self) -> list:
        return list(self.spans)


class NullRecorder(SpanRecorder):
    """No-op recorder bound outside an instrumented part (sim/thread
    payloads, direct calls in tests): the ``span`` blocks run, nothing is
    kept — un-instrumented code pays two clock reads and nothing else."""

    def add(self, kind: str, t0: float, t1: float):
        pass

    @contextmanager
    def span(self, kind: str):
        yield

    def export(self) -> list:
        return []


_NULL = NullRecorder()
_local = threading.local()


def current_recorder() -> SpanRecorder:
    """The recorder bound to this thread (a no-op one when none is)."""
    return getattr(_local, "recorder", None) or _NULL


def set_current(recorder) -> None:
    """Bind ``recorder`` to this thread (None unbinds).  The worker's part
    thread binds its recorder around the payload call so nested library code
    (e.g. the shuffle's SpillBuffer) records spans without plumbing."""
    _local.recorder = recorder


@contextmanager
def bound(recorder):
    """Scoped :func:`set_current` — restores the previous binding on exit."""
    prev = getattr(_local, "recorder", None)
    _local.recorder = recorder
    try:
        yield recorder
    finally:
        _local.recorder = prev


def align(spans, offset: float, **tags) -> list:
    """Shift raw worker spans into the parent clock and attach identity
    tags: ``[(kind, t0, t1), ...] + offset -> [{kind, t0, t1, **tags}]``.
    Pure addition — relative order and nesting are preserved exactly (the
    property the flight-recorder tests check)."""
    return [dict(kind=k, t0=t0 + offset, t1=t1 + offset, **tags)
            for k, t0, t1 in spans]
