"""Counter/gauge registry — the telemetry side of the flight recorder.

One :class:`MetricsRegistry` per worker process aggregates what used to be
ad-hoc scattered attributes: ``ProcTaskComm``'s per-part counters now write
through a part-local registry *chained* to the worker registry, so the
worker-lifetime totals the heartbeat snapshots (queue depth, RSS, spill
bytes, peer-channel cache size, ``p2p_fallbacks``) stay consistent with the
per-part numbers shipped on PART_DONE without double bookkeeping.

``snapshot()`` is what a telemetry-carrying HEARTBEAT frame embeds: all
counters plus every registered gauge evaluated at call time.  Gauges are
plain callables (``lambda: len(self._tasks)``) so a stuck or swapping worker
reports its true current state, not a stale cache.
"""
from __future__ import annotations

import os
from typing import Callable, Optional


class MetricsRegistry:
    """Named monotonically-increasing counters + lazily-evaluated gauges.

    ``parent`` chains registries: every counter increment is mirrored into
    the parent, which is how per-part accounting (shipped on PART_DONE)
    also feeds the worker-lifetime totals the heartbeat reports.  Plain
    int ``+=`` under the GIL — the same atomicity story the ad-hoc
    attributes had, with one writer thread per part in practice.
    """

    def __init__(self, parent: Optional["MetricsRegistry"] = None):
        self.parent = parent
        self._counters: dict[str, int] = {}
        self._gauges: dict[str, Callable[[], float]] = {}

    # -- counters ----------------------------------------------------------
    def inc(self, name: str, value: int = 1):
        if value:
            self._counters[name] = self._counters.get(name, 0) + value
            if self.parent is not None:
                self.parent.inc(name, value)

    def get(self, name: str) -> int:
        return self._counters.get(name, 0)

    def set_counter(self, name: str, value: int):
        """Absolute assignment with parent-consistent semantics: the parent
        receives the *delta* — what backs the ``comm.spills += n`` style
        attribute surface on :class:`ProcTaskComm`."""
        self.inc(name, int(value) - self._counters.get(name, 0))

    # -- gauges ------------------------------------------------------------
    def gauge(self, name: str, fn: Callable[[], float]):
        self._gauges[name] = fn

    # -- snapshot ----------------------------------------------------------
    def snapshot(self) -> dict:
        """Counters + gauges as one flat dict (the HEARTBEAT payload).  A
        gauge that raises reports -1 rather than killing the heartbeat
        loop — liveness must never depend on telemetry health."""
        out = dict(self._counters)
        for name, fn in self._gauges.items():
            try:
                out[name] = fn()
            except Exception:  # noqa: BLE001 — telemetry must not kill liveness
                out[name] = -1
        return out


def rss_mb() -> float:
    """Current resident set size in MiB — /proc-based on Linux (true current
    RSS, the early-warning signal for a swapping worker), ``ru_maxrss``
    high-water fallback elsewhere."""
    try:
        with open("/proc/self/statm") as f:
            pages = int(f.read().split()[1])
        return pages * os.sysconf("SC_PAGE_SIZE") / (1 << 20)
    except (OSError, ValueError, IndexError):
        try:
            import resource
            ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
            # linux reports KiB, macOS bytes
            return ru / (1 << 10) if ru < (1 << 34) else ru / (1 << 20)
        except Exception:  # noqa: BLE001 — telemetry is best-effort
            return -1.0
