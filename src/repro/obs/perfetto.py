"""Chrome/Perfetto export of a recorded trace.

Produces the JSON object format (``{"traceEvents": [...]}``) both
``chrome://tracing`` and https://ui.perfetto.dev load directly:

* one *process* row per worker, holding that worker's span lanes (``X``
  complete events; concurrent parts get separate ``tid`` lanes),
* a ``scheduler`` process whose lanes carry the dispatch->terminal slice of
  every task (reconstructed from the event stream — present even for
  span-less sim/thread traces) plus instant markers for the pool-level
  events (grow/retire/device_failure/steal/return),
* counter tracks (``C`` events) for every telemetry gauge a worker
  reported — queue depth, RSS, spill bytes, peer-channel cache size,
  ``p2p_fallbacks`` — so a stuck or swapping worker is visible as a flat
  or climbing counter next to its silent span lane.

CLI: ``python -m repro.obs.perfetto run.jsonl [-o trace.json]``.
"""
from __future__ import annotations

import json

_US = 1e6   # trace timestamps are seconds; Chrome wants microseconds

#: pool-level event kinds rendered as instant markers on the scheduler row
INSTANT_KINDS = ("device_failure", "grow", "retire", "steal", "return",
                 "speculate", "retry", "cancel")


def _lanes(intervals):
    """Greedy lane assignment for possibly-overlapping ``(t0, t1, ...)``
    intervals: earliest-start first, each taking the lowest lane free at its
    start — one row per *concurrent* occupant, stable across runs."""
    out = []
    lane_free: list = []             # lane -> time it frees up
    for iv in sorted(intervals, key=lambda x: (x[0], x[1])):
        for i, free_at in enumerate(lane_free):
            if iv[0] >= free_at:
                lane_free[i] = iv[1]
                out.append((i, iv))
                break
        else:
            lane_free.append(iv[1])
            out.append((len(lane_free) - 1, iv))
    return out


def export_perfetto(rec, path=None) -> dict:
    """Convert ``rec`` (a :class:`repro.obs.trace.RecordedTrace`, or any
    object with ``.trace``/``.spans``/``.telemetry`` — a live ``SimReport``
    works too) to the Chrome trace dict; written to ``path`` if given."""
    events = []
    pids: dict[str, int] = {}

    def pid_of(name: str) -> int:
        if name not in pids:
            pids[name] = len(pids)
            events.append({"ph": "M", "pid": pids[name], "name":
                           "process_name", "args": {"name": name}})
        return pids[name]

    sched = pid_of("scheduler")

    # --- scheduler rows: task slices from dispatch -> terminal ------------
    trace = list(getattr(rec, "trace", ()))
    open_at: dict = {}
    slices = []
    for e in trace:
        if e.kind in ("dispatch", "speculate"):
            open_at[e.uid] = e
        elif e.kind in ("done", "fail", "cancel", "retry") and \
                e.uid in open_at:
            d = open_at.pop(e.uid)
            slices.append((d.t, max(e.t, d.t + 1e-9), d, e.kind))
        elif e.kind in INSTANT_KINDS:
            events.append({"ph": "i", "ts": e.t * _US, "pid": sched,
                           "tid": 0, "s": "p", "cat": "scheduler",
                           "name": e.kind,
                           "args": {"task": e.task, "value": e.value}})
    t_end = max((e.t for e in trace), default=0.0)
    for uid, d in open_at.items():   # still running at trace end (crash)
        slices.append((d.t, max(t_end, d.t + 1e-9), d, "truncated"))
    for lane, (t0, t1, d, outcome) in _lanes(slices):
        events.append({"ph": "X", "ts": t0 * _US, "dur": (t1 - t0) * _US,
                       "pid": sched, "tid": lane, "cat": "task",
                       "name": d.task or f"uid{d.uid}",
                       "args": {"uid": d.uid, "ranks": d.ranks,
                                "pipeline": d.pipeline, "outcome": outcome}})

    # --- worker rows: spans, one tid lane per concurrent part -------------
    by_worker: dict[str, list] = {}
    for s in getattr(rec, "spans", ()) or ():
        by_worker.setdefault(s.get("worker", "worker"), []).append(s)
    for wid in sorted(by_worker):
        pid = pid_of(f"worker {wid}")
        # parts sharing a (uid, part) run on one lane; concurrent parts on
        # the worker each get their own
        part_iv: dict = {}
        for s in by_worker[wid]:
            key = (s.get("uid", -1), s.get("part", 0))
            lo, hi = part_iv.get(key, (s["t0"], s["t1"]))
            part_iv[key] = (min(lo, s["t0"]), max(hi, s["t1"]))
        lane_of = {key: lane for lane, (_, _, key) in
                   _lanes([(lo, hi, key) for key, (lo, hi)
                           in part_iv.items()])}
        for s in by_worker[wid]:
            key = (s.get("uid", -1), s.get("part", 0))
            events.append({"ph": "X", "ts": s["t0"] * _US,
                           "dur": max(s["t1"] - s["t0"], 0.0) * _US,
                           "pid": pid, "tid": lane_of[key], "cat": "span",
                           "name": s["kind"],
                           "args": {"task": s.get("task", ""),
                                    "uid": s.get("uid", -1),
                                    "part": s.get("part", 0)}})

    # --- counter tracks: one per (worker, gauge) ---------------------------
    for rec_t in getattr(rec, "telemetry", ()) or ():
        wid = rec_t.get("worker", "worker")
        pid = pid_of(f"worker {wid}")
        t = rec_t.get("t", 0.0)
        for k, v in rec_t.items():
            if k in ("worker", "t") or not isinstance(v, (int, float)):
                continue
            events.append({"ph": "C", "ts": t * _US, "pid": pid,
                           "name": k, "args": {"value": v}})

    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if path is not None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(out, f)
    return out


def main(argv=None):
    import argparse

    from repro.obs.trace import load_trace

    p = argparse.ArgumentParser(
        description="Export a flight-recorder JSONL trace to "
                    "Chrome/Perfetto trace.json")
    p.add_argument("jsonl", help="recorded trace (REPRO_TRACE output)")
    p.add_argument("-o", "--out", default=None,
                   help="output path (default: <jsonl>.trace.json)")
    a = p.parse_args(argv)
    out = a.out or (a.jsonl.rsplit(".jsonl", 1)[0] + ".trace.json")
    doc = export_perfetto(load_trace(a.jsonl), out)
    print(f"{out}: {len(doc['traceEvents'])} events")


if __name__ == "__main__":
    main()
