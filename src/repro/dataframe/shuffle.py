"""Out-of-core peer-to-peer shuffle: the paper's shuffle-heavy operators
(`dist_sort`, `dist_join`) at row counts that no longer fit the in-memory
``ops_dist`` path (Radical-Cylon's 35M/3.5B-row claim surface).

Pipeline, per task part (one per worker):

  1. **local bucketing** — :func:`radix_bucket` wires the Pallas
     ``radix_partition`` kernel in as the packing stage: one kernel call
     yields bucket-major stable destinations + histogram, one gather lays
     the columns out bucket-major, and each destination's bucket is a
     CONTIGUOUS slice of that layout.  This replaces the argsort-based
     ``_local_shuffle_pack`` on this path — no (P, send_cap) padded send
     buffer, no fixed capacity, no overflow case.
  2. **exchange** — ``comm.all_to_all_arrays`` ships each bucket as ONE
     raw-buffer peer frame (``PEER_DATA_RAW``: dtype/shape header +
     memoryview body, no pickle round-trip) with per-payload fallback to
     the pickled hub path.
  3. **spill** — received runs land in a :class:`SpillBuffer`; above the
     per-worker budget (``REPRO_SHUFFLE_BUDGET``) runs spill to disk as
     per-column ``.npy`` files and are read back memory-mapped, so the
     merge never needs the whole partition resident.
  4. **stream-merge** — :meth:`SpillBuffer.merge_sorted` k-way merges the
     sorted runs in bounded chunks; :func:`merge_join_sorted` merge-joins
     two such streams for ``dist_join``.

The task payloads (:func:`sort_task`, :func:`join_task`) generate their
input deterministically from ``(seed, part)`` — a SIGKILLed worker's retry
(same uid, new attempt, surviving workers) reproduces the identical global
result, which is what the recovery tests assert.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time
from time import perf_counter

import numpy as np

from repro.obs.spans import current_recorder

_HASH_MULT = np.uint32(2654435761)

DEFAULT_BUDGET = 64 << 20   # 64 MiB per worker unless REPRO_SHUFFLE_BUDGET


def parse_budget(s, default: int = DEFAULT_BUDGET) -> int:
    """``REPRO_SHUFFLE_BUDGET`` parser: plain bytes or k/m/g suffix
    (``"32m"``, ``"256K"``, ``"1g"``, ``"1048576"``)."""
    if s is None or s == "":
        return default
    if isinstance(s, int):
        return s
    t = str(s).strip().lower().rstrip("b")
    scale = 1
    if t and t[-1] in "kmg":
        scale = {"k": 1 << 10, "m": 1 << 20, "g": 1 << 30}[t[-1]]
        t = t[:-1]
    return int(float(t) * scale)


def hash32(key: np.ndarray) -> np.ndarray:
    """Knuth multiplicative hash -> uint32; the numpy twin of
    ``ops_local.hash_key`` so both shuffle paths partition identically."""
    k = key.astype(np.uint32)
    with np.errstate(over="ignore"):
        h = (k * _HASH_MULT) ^ (k >> np.uint32(16))
        return h * _HASH_MULT


# ---------------------------------------------------------------------------
# 1. local bucketing: the Pallas radix-partition packing stage
# ---------------------------------------------------------------------------
def radix_bucket(cols: dict, buckets: np.ndarray, n_buckets: int, *,
                 block: int = 4096, interpret=None, verify: bool = False):
    """Bucket-major local packing via the Pallas ``radix_partition`` kernel.

    ``cols`` is a dict name -> (n,)-leading np array, ``buckets`` the (n,)
    int32 destination of each row.  Returns ``(chunks, hist)``: ``chunks[j]``
    holds bucket j's rows (original order preserved — the kernel's ranks are
    stable) as contiguous arrays ready for a raw peer frame, ``hist`` the
    rows-per-bucket histogram.

    ``interpret`` defaults to True off-TPU (the workers run
    ``JAX_PLATFORMS=cpu``); ``verify=True`` cross-checks the kernel output
    bit-for-bit against the pure-jnp ``ref.py`` oracle and raises on any
    mismatch — the acceptance hook the shuffle tests flip on.
    """
    import jax
    import jax.numpy as jnp

    from repro.kernels.radix_partition.ops import radix_partition

    n = int(len(buckets))
    if n == 0:
        return ([{k: np.asarray(v)[:0] for k, v in cols.items()}
                 for _ in range(n_buckets)],
                np.zeros(n_buckets, np.int64))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b = jnp.asarray(np.ascontiguousarray(buckets, np.int32))
    dest, hist = radix_partition(b, n_buckets, block=block,
                                 interpret=interpret)
    dest = np.asarray(dest)
    hist = np.asarray(hist, np.int64)
    if verify:
        from repro.kernels.radix_partition.ref import destinations_ref
        dref, href = destinations_ref(b, n_buckets)
        if not (np.array_equal(dest, np.asarray(dref))
                and np.array_equal(hist, np.asarray(href, np.int64))):
            raise AssertionError(
                "radix_partition kernel output diverges from ref.py")
    perm = np.empty(n, np.int64)
    perm[dest] = np.arange(n)
    offs = np.concatenate([[0], np.cumsum(hist)])
    chunks = []
    major = {k: np.asarray(v)[perm] for k, v in cols.items()}
    for j in range(n_buckets):
        lo, hi = int(offs[j]), int(offs[j + 1])
        chunks.append({k: v[lo:hi] for k, v in major.items()})
    return chunks, hist


# ---------------------------------------------------------------------------
# 3. spill: bounded-memory run store
# ---------------------------------------------------------------------------
class SpillBuffer:
    """Received shuffle runs under a byte budget, spilled to disk beyond it.

    Each :meth:`add` stores one run SORTED by ``key``.  While the resident
    total stays under ``budget_bytes`` runs are kept in memory; a run that
    would cross the budget is written as per-column ``.npy`` files and read
    back memory-mapped, so :meth:`merge_sorted` touches only the pages each
    merge chunk needs.  ``spills`` counts spilled runs — the tasks add it to
    ``comm.spills`` so the evidence reaches the scheduler trace."""

    def __init__(self, budget_bytes: int, key: str, spill_dir=None):
        self.budget = int(budget_bytes)
        self.key = key
        self.runs: list[dict] = []
        self.spills = 0
        self.spill_bytes = 0     # bytes written to disk across spilled runs
        # (the telemetry counter a worker's heartbeat reports)
        self._mem = 0
        self._dir = spill_dir
        self._own_dir = spill_dir is None

    def _spill_path(self) -> str:
        if self._dir is None:
            self._dir = tempfile.mkdtemp(prefix="repro-shuffle-")
        return self._dir

    def add(self, run: dict):
        """Add one run (dict name -> (n,)-leading arrays, any order)."""
        k = np.asarray(run[self.key])
        if len(k) == 0:
            return
        order = np.argsort(k, kind="stable")
        srun = {name: np.ascontiguousarray(np.asarray(v)[order])
                for name, v in run.items()}
        nbytes = sum(v.nbytes for v in srun.values())
        if self._mem + nbytes > self.budget:
            # the thread-bound flight recorder (a no-op outside instrumented
            # worker parts) times the disk write — no parameter plumbing
            with current_recorder().span("spill_write"):
                d = self._spill_path()
                i = self.spills
                mapped = {}
                for name, v in srun.items():
                    path = os.path.join(d, f"run{i}_{name}.npy")
                    np.save(path, v)
                    mapped[name] = np.load(path, mmap_mode="r")
            self.runs.append(mapped)
            self.spills += 1
            self.spill_bytes += nbytes
        else:
            self.runs.append(srun)
            self._mem += nbytes

    def merge_sorted(self, chunk_rows: int = 65536):
        """Yield dict chunks in global key order (k-way merge of the sorted
        runs), never materializing more than ~``chunk_rows`` rows per run.

        Boundary rule: a chunk may emit only keys <= the smallest
        "last loaded key" among runs that still have UNLOADED rows — any
        unloaded row's key is >= its run's last loaded key, so nothing
        yielded later can sort before what was emitted."""
        runs = [r for r in self.runs if len(r[self.key])]
        if not runs:
            return
        totals = [len(r[self.key]) for r in runs]
        cursors = [0] * len(runs)
        bufs: list = [None] * len(runs)

        def load(i):
            lo = cursors[i]
            hi = min(lo + chunk_rows, totals[i])
            cursors[i] = hi
            return {k: np.asarray(v[lo:hi]) for k, v in runs[i].items()}

        rec = current_recorder()
        while True:
            t0 = perf_counter()
            for i in range(len(runs)):
                if (bufs[i] is None or len(bufs[i][self.key]) == 0) \
                        and cursors[i] < totals[i]:
                    bufs[i] = load(i)
            active = [i for i in range(len(runs))
                      if bufs[i] is not None and len(bufs[i][self.key])]
            if not active:
                return
            bounds = [bufs[i][self.key][-1] for i in active
                      if cursors[i] < totals[i]]
            pieces = []
            for i in active:
                bk = bufs[i][self.key]
                cut = len(bk) if not bounds else int(
                    np.searchsorted(bk, min(bounds), side="right"))
                if cut:
                    pieces.append({k: v[:cut] for k, v in bufs[i].items()})
                    bufs[i] = {k: v[cut:] for k, v in bufs[i].items()}
            # progress is guaranteed: the run attaining min(bounds) always
            # emits through its last loaded row
            out = {k: np.concatenate([p[k] for p in pieces])
                   for k in pieces[0]}
            order = np.argsort(out[self.key], kind="stable")
            chunk = {k: v[order] for k, v in out.items()}
            # explicit add (not the with-form): a context manager spanning
            # the yield would charge the CONSUMER's work to the merge span
            rec.add("merge", t0, perf_counter())
            yield chunk

    def close(self):
        self.runs = []
        if self._own_dir and self._dir is not None:
            shutil.rmtree(self._dir, ignore_errors=True)
            self._dir = None


# ---------------------------------------------------------------------------
# 4. stream merge-join of two sorted run streams
# ---------------------------------------------------------------------------
def _join_sorted(lc: dict, rc: dict, key: str) -> dict:
    """Inner join of two key-sorted chunks (duplicate keys -> cross
    product).  Column naming matches ``ops_local.join_inner``: the key is
    kept once, colliding value columns get l_/r_ prefixes."""
    lk, rk = lc[key], rc[key]
    lo = np.searchsorted(rk, lk, side="left")
    hi = np.searchsorted(rk, lk, side="right")
    counts = hi - lo
    total = int(counts.sum())
    li = np.repeat(np.arange(len(lk)), counts)
    ends = np.cumsum(counts)
    ri = (lo[li] + (np.arange(total) - (ends - counts)[li])) \
        if total else np.zeros(0, np.int64)
    out = {key: lk[li]}
    for name, v in lc.items():
        if name != key:
            out[f"l_{name}" if name in rc else name] = v[li]
    for name, v in rc.items():
        if name != key:
            out[f"r_{name}" if name in lc else name] = v[ri]
    return out


def merge_join_sorted(liter, riter, key: str):
    """Streaming inner join of two iterators of key-sorted chunks (e.g. two
    :meth:`SpillBuffer.merge_sorted` streams); yields joined chunks.

    Keys strictly below ``min(last loaded key of each unfinished side)``
    are complete on both sides and can be joined and discarded; an
    equal-key group straddling a chunk boundary stays in the carry buffer
    until the bound moves past it."""
    def pull(it):
        try:
            return next(it)
        except StopIteration:
            return None

    def cat(a, b):
        return {k: np.concatenate([a[k], b[k]]) for k in a}

    lbuf = rbuf = None
    ldone = rdone = False
    while True:
        if (lbuf is None or len(lbuf[key]) == 0) and not ldone:
            nxt = pull(liter)
            if nxt is None:
                ldone = True
            else:
                lbuf = nxt if lbuf is None or len(lbuf[key]) == 0 \
                    else cat(lbuf, nxt)
                continue
        if (rbuf is None or len(rbuf[key]) == 0) and not rdone:
            nxt = pull(riter)
            if nxt is None:
                rdone = True
            else:
                rbuf = nxt if rbuf is None or len(rbuf[key]) == 0 \
                    else cat(rbuf, nxt)
                continue
        lempty = lbuf is None or len(lbuf[key]) == 0
        rempty = rbuf is None or len(rbuf[key]) == 0
        if (lempty and ldone) or (rempty and rdone):
            return
        bounds = []
        if not ldone:
            bounds.append(lbuf[key][-1])
        if not rdone:
            bounds.append(rbuf[key][-1])
        if not bounds:
            chunk = _join_sorted(lbuf, rbuf, key)
            lbuf = {k: v[:0] for k, v in lbuf.items()}
            rbuf = {k: v[:0] for k, v in rbuf.items()}
            if len(chunk[key]):
                yield chunk
            continue
        bound = min(bounds)
        lcut = int(np.searchsorted(lbuf[key], bound, side="left"))
        rcut = int(np.searchsorted(rbuf[key], bound, side="left"))
        if lcut == 0 and rcut == 0:
            # every buffered key is >= bound: the side whose last key IS the
            # bound may still have unloaded duplicates — extend it
            if not ldone and (rdone or lbuf[key][-1] <= rbuf[key][-1]):
                nxt = pull(liter)
                if nxt is None:
                    ldone = True
                else:
                    lbuf = cat(lbuf, nxt)
            else:
                nxt = pull(riter)
                if nxt is None:
                    rdone = True
                else:
                    rbuf = cat(rbuf, nxt)
            continue
        chunk = _join_sorted({k: v[:lcut] for k, v in lbuf.items()},
                             {k: v[:rcut] for k, v in rbuf.items()}, key)
        lbuf = {k: v[lcut:] for k, v in lbuf.items()}
        rbuf = {k: v[rcut:] for k, v in rbuf.items()}
        if len(chunk[key]):
            yield chunk


# ---------------------------------------------------------------------------
# task payloads (ProcessExecutor; deterministic per (seed, part))
# ---------------------------------------------------------------------------
def _gen_part(spec: dict, part: int, side: int = 0) -> dict:
    """Deterministic per-(seed, part, side) row block: an int32 ``key``
    column plus ``payload_width`` int64 value columns (``v0..`` on side 0,
    ``w0..`` on side 1 so join outputs need no renames)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([int(spec.get("seed", 0)), part, side]))
    n = int(spec["rows_per_part"])
    key_range = int(spec.get("key_range", max(4 * n, 16)))
    cols = {"key": rng.integers(0, key_range, n, dtype=np.int32)}
    prefix = "v" if side == 0 else "w"
    for j in range(int(spec.get("payload_width", 1))):
        cols[f"{prefix}{j}"] = rng.integers(0, 1 << 30, n, dtype=np.int64)
    return cols


def _budget(spec: dict) -> int:
    return spec["budget"] if spec.get("budget") is not None else \
        parse_budget(os.environ.get("REPRO_SHUFFLE_BUDGET"))


def _exchange(comm, chunks: list) -> list:
    """Ship per-destination chunks through the comm (raw peer frames with
    pickled fallback); outside a ProcessExecutor part there is nothing to
    exchange and the local chunks come straight back."""
    if hasattr(comm, "all_to_all_arrays"):
        return comm.all_to_all_arrays(chunks)
    return chunks


def _u64sum(a: np.ndarray) -> int:
    return int(np.bitwise_and(
        np.add.reduce(a.astype(np.uint64), dtype=np.uint64),
        np.uint64(0xFFFFFFFFFFFFFFFF)))


def sort_task(comm, spec: dict) -> dict:
    """Distributed sample sort, out-of-core: deterministic local rows ->
    splitters from an allgathered sample -> radix_bucket -> raw-frame
    exchange -> SpillBuffer -> streamed merge.  Returns a global summary
    (row count, uint64 key checksum, sortedness incl. part boundaries,
    spill count) identical with or without spilling; ``collect=True``
    additionally returns the fully sorted rows (small sizes / tests)."""
    part = getattr(comm, "part", 0)
    n_parts = getattr(comm, "n_parts", 1)
    cols = _gen_part(spec, part)
    keys = cols["key"]
    # splitters: even quantiles of the allgathered per-part sample
    oversample = 32
    sk = np.sort(keys)
    q = (np.arange(n_parts * oversample) + 0.5) / (n_parts * oversample)
    samples = sk[np.clip((q * len(sk)).astype(np.int64), 0,
                         max(len(sk) - 1, 0))] if len(sk) else sk
    if n_parts > 1:
        samples = np.sort(np.concatenate(comm.allgather(samples)))
    splitters = samples[(np.arange(1, n_parts) * len(samples)) // n_parts] \
        if len(samples) else np.zeros(n_parts - 1, keys.dtype)
    target = np.searchsorted(splitters, keys, side="right").astype(np.int32)
    chunks, _ = radix_bucket(cols, target, n_parts,
                             block=int(spec.get("block", 4096)),
                             verify=bool(spec.get("verify_kernel", False)))
    received = _exchange(comm, chunks)
    buf = SpillBuffer(_budget(spec), "key")
    try:
        for run in received:
            buf.add(run)
        if spec.get("stall_s"):     # kill-mid-shuffle test hook: spilled
            time.sleep(float(spec["stall_s"]))  # buckets exist right now
        total, ksum, first, last = 0, 0, None, None
        ordered = True
        prev_last = None
        collected = []
        for chunk in buf.merge_sorted(int(spec.get("chunk_rows", 65536))):
            k = chunk["key"]
            ordered = ordered and bool(np.all(k[1:] >= k[:-1])) and \
                (prev_last is None or k[0] >= prev_last)
            prev_last = k[-1]
            total += len(k)
            ksum = (ksum + _u64sum(k)) & 0xFFFFFFFFFFFFFFFF
            first = int(k[0]) if first is None else first
            last = int(k[-1])
            if spec.get("collect"):
                collected.append(chunk)
        if hasattr(comm, "spills"):
            comm.spills += buf.spills
        if hasattr(comm, "metrics"):
            comm.metrics.inc("spill_bytes", buf.spill_bytes)
        summary = {"part": part, "n": total, "key_sum": ksum, "min": first,
                   "max": last, "sorted": ordered, "spills": buf.spills}
        if spec.get("collect"):
            names = list(cols)
            summary["rows"] = {
                k: (np.concatenate([c[k] for c in collected])
                    if collected else np.zeros(0, cols[k].dtype))
                for k in names}
    finally:
        buf.close()
    parts = comm.allgather(summary) if n_parts > 1 else [summary]
    parts.sort(key=lambda s: s["part"])
    edges_ok = all(
        a["max"] is None or b["min"] is None or a["max"] <= b["min"]
        for a, b in zip(parts[:-1], parts[1:], strict=True))
    out = {"n": sum(s["n"] for s in parts),
           "key_sum": sum(s["key_sum"] for s in parts) & 0xFFFFFFFFFFFFFFFF,
           "sorted": all(s["sorted"] for s in parts) and edges_ok,
           "spills": sum(s["spills"] for s in parts)}
    if spec.get("collect"):
        out["rows"] = {k: np.concatenate([s["rows"][k] for s in parts])
                       for k in parts[0]["rows"]}
    return out


def join_task(comm, spec: dict) -> dict:
    """Distributed hash join, out-of-core: both sides hash-partitioned with
    :func:`hash32` (the ``ops_local.hash_key`` twin), radix-bucketed,
    exchanged as raw frames, spilled under the budget, and merge-joined
    from the two sorted streams.  Summary checksums (row count, uint64 sums
    of key and both value columns) are identical with or without spill."""
    part = getattr(comm, "part", 0)
    n_parts = getattr(comm, "n_parts", 1)
    left = _gen_part(spec, part, side=0)
    rspec = dict(spec)
    rspec["rows_per_part"] = int(
        spec.get("right_rows_per_part", spec["rows_per_part"]))
    right = _gen_part(rspec, part, side=1)
    budget = _budget(spec)
    lbuf = SpillBuffer(budget, "key")
    rbuf = SpillBuffer(budget, "key")
    try:
        for table, buf in ((left, lbuf), (right, rbuf)):
            tgt = (hash32(table["key"]) % np.uint32(n_parts)).astype(np.int32)
            chunks, _ = radix_bucket(table, tgt, n_parts,
                                     block=int(spec.get("block", 4096)),
                                     verify=bool(spec.get("verify_kernel",
                                                          False)))
            for run in _exchange(comm, chunks):
                buf.add(run)
        if spec.get("stall_s"):
            time.sleep(float(spec["stall_s"]))
        chunk_rows = int(spec.get("chunk_rows", 65536))
        total, ksum, vsum, wsum = 0, 0, 0, 0
        collected = []
        for chunk in merge_join_sorted(lbuf.merge_sorted(chunk_rows),
                                       rbuf.merge_sorted(chunk_rows), "key"):
            total += len(chunk["key"])
            ksum = (ksum + _u64sum(chunk["key"])) & 0xFFFFFFFFFFFFFFFF
            vsum = (vsum + _u64sum(chunk["v0"])) & 0xFFFFFFFFFFFFFFFF
            wsum = (wsum + _u64sum(chunk["w0"])) & 0xFFFFFFFFFFFFFFFF
            if spec.get("collect"):
                collected.append(chunk)
        if hasattr(comm, "spills"):
            comm.spills += lbuf.spills + rbuf.spills
        if hasattr(comm, "metrics"):
            comm.metrics.inc("spill_bytes",
                             lbuf.spill_bytes + rbuf.spill_bytes)
        summary = {"part": part, "n": total, "key_sum": ksum,
                   "v_sum": vsum, "w_sum": wsum,
                   "spills": lbuf.spills + rbuf.spills}
        if spec.get("collect"):
            summary["rows"] = {
                k: (np.concatenate([c[k] for c in collected])
                    if collected else np.zeros(0, np.int64))
                for k in (collected[0] if collected
                          else {"key": None, "v0": None, "w0": None})}
    finally:
        lbuf.close()
        rbuf.close()
    parts = comm.allgather(summary) if n_parts > 1 else [summary]
    parts.sort(key=lambda s: s["part"])
    out = {"n": sum(s["n"] for s in parts),
           "key_sum": sum(s["key_sum"] for s in parts) & 0xFFFFFFFFFFFFFFFF,
           "v_sum": sum(s["v_sum"] for s in parts) & 0xFFFFFFFFFFFFFFFF,
           "w_sum": sum(s["w_sum"] for s in parts) & 0xFFFFFFFFFFFFFFFF,
           "spills": sum(s["spills"] for s in parts)}
    if spec.get("collect"):
        out["rows"] = {k: np.concatenate([s["rows"][k] for s in parts])
                       for k in parts[0]["rows"]}
    return out
