"""Channel abstraction over jax.lax collectives — the analogue of Cylon's
MPI/UCX/GLOO communicator layer.  All distributed operators go through these
four primitives, so the 'transport' is swappable and mockable (single point
of instrumentation for the collective-traffic accounting in benchmarks/).
"""
from __future__ import annotations

import jax


def all_to_all(x, axis: str):
    """x (P, c, ...) per rank -> chunk j goes to rank j; returns (P, c, ...)"""
    return jax.lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=False)


def all_gather(x, axis: str):
    return jax.lax.all_gather(x, axis)


def psum(x, axis: str):
    return jax.lax.psum(x, axis)


def pmax(x, axis: str):
    return jax.lax.pmax(x, axis)


def axis_index(axis: str):
    return jax.lax.axis_index(axis)


def axis_size(axis: str):
    return jax.lax.axis_size(axis)
