"""Cylon 'distributed operators': BSP SPMD programs under shard_map.

Each operator is built for a Communicator (the private per-task mesh the
runtime delivers) and runs as one jit'd shard_map program over the 'df' axis:

  * shuffle       — hash/range repartition rows via all_to_all
  * dist_sort     — sample sort: local sort -> splitter all_gather -> range
                    shuffle -> local sort  (globally sorted across ranks)
  * dist_join     — hash-shuffle both sides, local sort-merge inner join
  * dist_groupby  — hash shuffle + local segmented sum

Static shapes: every rank holds (capacity,) padded columns + nrows.  Send
buffers have per-destination capacity slack; overflow is detected and
reported (overflow flag), never silently dropped.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.dataframe import comm
from repro.dataframe import ops_local as L
from repro.dataframe.table import Table


class ShuffleOverflow(RuntimeError):
    """A shuffle dropped rows: some rank's per-destination row count
    exceeded its send-buffer capacity (``counts > send_cap``).  Carries the
    structured context callers need to retry with more slack — or to switch
    to the out-of-core path (``repro.dataframe.shuffle``), which has no
    fixed send capacity at all."""

    def __init__(self, op: str, slack: float):
        self.op = op
        self.slack = slack
        super().__init__(
            f"{op}: send buffer overflow (some rank's rows for one "
            f"destination exceeded capacity * slack / n_parts with "
            f"slack={slack}); retry with a larger slack= or use the "
            f"out-of-core shuffle (repro.dataframe.shuffle)")


def _checked(fn, op: str, slack: float, on_overflow: str):
    """Wrap a jitted ``(table, ovf)`` op: ``on_overflow="return"`` keeps the
    legacy pass-through; ``"raise"`` turns a True overflow flag into a
    :class:`ShuffleOverflow` so it can never be silently dropped."""
    if on_overflow not in ("return", "raise"):
        raise ValueError(f"on_overflow={on_overflow!r} "
                         "(expected 'return' or 'raise')")
    if on_overflow == "return":
        return fn

    def wrapped(*args):
        out, ovf = fn(*args)
        if bool(ovf):
            raise ShuffleOverflow(op, slack)
        return out, ovf

    return wrapped


def _unit_nrows(t: Table) -> Table:
    """Inside shard_map each rank's nrows must be rank-1 (length 1) so the
    out_specs concatenation over the df axis yields a (P,) vector outside."""
    return Table(columns=t.columns, nrows=t.nrows.reshape(1).astype(jnp.int32))


def _table_spec(axis: str):
    # columns sharded on rows over the df axis; nrows is per-rank (one scalar
    # per shard stored as a (P,) vector)
    return P(axis)


# ---------------------------------------------------------------------------
# shuffle
# ---------------------------------------------------------------------------
def _local_shuffle_pack(table: Table, target, n_parts: int, send_cap: int):
    """Pack rows into a (P, send_cap, ...) send buffer by destination."""
    cap = table.capacity
    valid = table.valid_mask()
    tgt = jnp.where(valid, target, n_parts)          # invalid -> dropped
    order = jnp.argsort(jnp.where(valid, tgt, n_parts), stable=True)
    sorted_t = tgt[order]
    start = jnp.searchsorted(sorted_t, jnp.arange(n_parts), side="left")
    pos_sorted = jnp.arange(cap) - start[jnp.minimum(sorted_t, n_parts - 1)]
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    counts = jnp.bincount(jnp.where(valid, tgt, n_parts), length=n_parts + 1)[:n_parts]
    overflow = jnp.any(counts > send_cap)

    bufs = {}
    row_ok = valid & (pos < send_cap)
    e = jnp.where(row_ok, tgt, n_parts)
    pp = jnp.where(row_ok, pos, 0)
    for k, v in table.columns.items():
        buf = jnp.zeros((n_parts, send_cap) + v.shape[1:], v.dtype)
        bufs[k] = buf.at[e, pp].set(v, mode="drop")
    sent = jnp.minimum(counts, send_cap).astype(jnp.int32)  # (P,) rows per dest
    return bufs, sent, overflow


def _shuffle_inside(table: Table, target, axis: str, slack: float):
    """Runs INSIDE shard_map. Returns (Table with capacity P*send_cap, overflow)."""
    n_parts = comm.axis_size(axis)
    send_cap = int(table.capacity * slack) // n_parts + 8
    bufs, sent, overflow = _local_shuffle_pack(table, target, n_parts, send_cap)
    recv = {k: comm.all_to_all(v, axis) for k, v in bufs.items()}   # (P, send_cap, ...)
    recv_counts = comm.all_to_all(sent.reshape(-1, 1), axis)[:, 0]  # (P,)
    # compact: rows arrive as P blocks with per-block validity
    pos_in_block = jnp.arange(send_cap)[None, :]
    rvalid = (pos_in_block < recv_counts[:, None]).reshape(-1)
    cols = {k: v.reshape((-1,) + v.shape[2:]) for k, v in recv.items()}
    # received rows are scattered across P blocks — mark ALL slots valid, then
    # compact by the true receive mask
    out = Table(columns=cols,
                nrows=jnp.asarray(rvalid.shape[0], jnp.int32))
    out = L.filter_rows(out, rvalid)
    return out, comm.psum(overflow.astype(jnp.int32), axis) > 0


def make_shuffle(mesh, axis: str = "df", slack: float = 2.0,
                 on_overflow: str = "return"):
    """Returns a jit'd shuffle(table, target) over the given mesh.
    ``on_overflow="raise"`` turns a dropped-rows overflow into a
    :class:`ShuffleOverflow` instead of a flag callers may ignore."""
    spec = P(axis)

    @partial(jax.shard_map, mesh=mesh, in_specs=(spec, spec), out_specs=(spec, P()),
             check_vma=False)
    def _shuf(table, target):
        out, ovf = _shuffle_inside(table, target, axis, slack)
        return _unit_nrows(out), ovf

    return _checked(jax.jit(_shuf), "shuffle", slack, on_overflow)


# ---------------------------------------------------------------------------
# distributed sample sort
# ---------------------------------------------------------------------------
def _dist_sort_inside(table: Table, key: str, axis: str, slack: float):
    n_parts = comm.axis_size(axis)
    ts = L.sort_by(table, key)
    # sample n_parts values per rank at even quantiles of the VALID rows
    q = (jnp.arange(n_parts) + 0.5) / n_parts
    idx = jnp.clip((q * jnp.maximum(ts.nrows, 1)).astype(jnp.int32), 0,
                   table.capacity - 1)
    samples = ts.columns[key][idx]                       # (P,)
    all_samples = comm.all_gather(samples, axis).reshape(-1)  # (P*P,)
    ssorted = jnp.sort(all_samples)
    splitters = ssorted[(jnp.arange(1, n_parts) * n_parts)]   # (P-1,)
    target = jnp.searchsorted(splitters, ts.columns[key], side="right")
    target = jnp.where(ts.valid_mask(), target.astype(jnp.int32), 0)
    shuffled, ovf = _shuffle_inside(ts, target, axis, slack)
    return L.sort_by(shuffled, key), ovf


def make_dist_sort(mesh, key: str, axis: str = "df", slack: float = 2.0,
                   on_overflow: str = "return"):
    spec = P(axis)

    @partial(jax.shard_map, mesh=mesh, in_specs=(spec,), out_specs=(spec, P()),
             check_vma=False)
    def _sort(table):
        out, ovf = _dist_sort_inside(table, key, axis, slack)
        return _unit_nrows(out), ovf

    return _checked(jax.jit(_sort), "dist_sort", slack, on_overflow)


# ---------------------------------------------------------------------------
# distributed hash join
# ---------------------------------------------------------------------------
def _dist_join_inside(left: Table, right: Table, key: str, axis: str,
                      slack: float, out_factor: float):
    n_parts = comm.axis_size(axis)

    def hash_target(t):
        h = (L.hash_key(t.columns[key]) % jnp.uint32(n_parts)).astype(jnp.int32)
        return jnp.where(t.valid_mask(), h, 0)

    ls, ovl = _shuffle_inside(left, hash_target(left), axis, slack)
    rs, ovr = _shuffle_inside(right, hash_target(right), axis, slack)
    out_cap = int(max(left.capacity, right.capacity) * out_factor)
    joined, ovj = L.join_inner(ls, rs, key, out_cap)
    ovf = ovl | ovr | (comm.psum(ovj.astype(jnp.int32), axis) > 0)
    return joined, ovf


def make_dist_join(mesh, key: str, axis: str = "df", slack: float = 2.0,
                   out_factor: float = 2.0, on_overflow: str = "return"):
    spec = P(axis)

    @partial(jax.shard_map, mesh=mesh, in_specs=(spec, spec),
             out_specs=(spec, P()), check_vma=False)
    def _join(left, right):
        out, ovf = _dist_join_inside(left, right, key, axis, slack, out_factor)
        return _unit_nrows(out), ovf

    return _checked(jax.jit(_join), "dist_join", slack, on_overflow)


# ---------------------------------------------------------------------------
# distributed groupby-sum
# ---------------------------------------------------------------------------
def make_dist_groupby_sum(mesh, key: str, value_cols, axis: str = "df",
                          slack: float = 2.0, on_overflow: str = "return"):
    spec = P(axis)

    @partial(jax.shard_map, mesh=mesh, in_specs=(spec,), out_specs=(spec, P()),
             check_vma=False)
    def _gb(table):
        n_parts = comm.axis_size(axis)
        h = (L.hash_key(table.columns[key]) % jnp.uint32(n_parts)).astype(jnp.int32)
        tgt = jnp.where(table.valid_mask(), h, 0)
        shuffled, ovf = _shuffle_inside(table, tgt, axis, slack)
        return _unit_nrows(L.groupby_sum(shuffled, key, value_cols)), ovf

    return _checked(jax.jit(_gb), "dist_groupby_sum", slack, on_overflow)


# ---------------------------------------------------------------------------
# host-side helpers: build a sharded global Table for a communicator
# ---------------------------------------------------------------------------
def shard_table(comm_obj, data: dict, capacity_per_rank: int) -> Table:
    """Round-robin partition host data into a (P*cap,) global Table placed on
    the communicator's mesh (leading dim sharded over 'df')."""
    import numpy as np
    from jax.sharding import NamedSharding

    n = len(next(iter(data.values())))
    pcount = comm_obj.size
    per = [n // pcount + (1 if r < n % pcount else 0) for r in range(pcount)]
    assert max(per) <= capacity_per_rank, (max(per), capacity_per_rank)
    cols = {}
    sharding = NamedSharding(comm_obj.mesh, P("df"))
    offs = np.cumsum([0] + per)
    for k, v in data.items():
        v = np.asarray(v)
        buf = np.zeros((pcount, capacity_per_rank) + v.shape[1:], v.dtype)
        for r in range(pcount):
            buf[r, :per[r]] = v[offs[r]:offs[r + 1]]
        cols[k] = jax.device_put(
            buf.reshape((pcount * capacity_per_rank,) + v.shape[1:]), sharding)
    nrows = jax.device_put(np.asarray(per, np.int32), sharding)
    return Table(columns=cols, nrows=nrows)


def collect_table(table: Table) -> dict:
    """Gather a distributed Table back to host as dict of np arrays (tests)."""
    import numpy as np
    nrows = np.asarray(table.nrows).reshape(-1)
    pcount = nrows.shape[0]
    out = {k: [] for k in table.columns}
    for k, v in table.columns.items():
        v = np.asarray(v).reshape((pcount, -1) + v.shape[1:])
        for r in range(pcount):
            out[k].append(v[r, :nrows[r]])
        out[k] = np.concatenate(out[k], axis=0)
    return out
