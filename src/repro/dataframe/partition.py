"""Partitioners: map rows -> destination rank (Cylon's shuffle targets)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.dataframe.ops_local import hash_key
from repro.dataframe.table import Table


def hash_partition(table: Table, key: str, n_parts: int) -> jnp.ndarray:
    """Destination rank per row (uint32 hash mod P); invalid rows -> 0."""
    tgt = (hash_key(table.columns[key]) % jnp.uint32(n_parts)).astype(jnp.int32)
    return jnp.where(table.valid_mask(), tgt, 0)


def range_partition(table: Table, key: str, splitters: jnp.ndarray) -> jnp.ndarray:
    """Destination = index of the splitter range containing the key.
    splitters: (P-1,) sorted.  Used by distributed sample-sort."""
    tgt = jnp.searchsorted(splitters, table.columns[key], side="right")
    return jnp.where(table.valid_mask(), tgt.astype(jnp.int32), 0)
