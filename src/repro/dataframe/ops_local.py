"""Cylon 'local operators': run on locally resident data only.
All static-shape: outputs are (capacity,)-padded with explicit nrows and an
overflow flag where the logical result size is data-dependent (join).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.dataframe.table import Table, key_sentinel

_HASH_MULT = jnp.uint32(2654435761)


def hash_key(key: jnp.ndarray) -> jnp.ndarray:
    """Knuth multiplicative hash -> uint32 (partitioner + hash-join)."""
    k = key.astype(jnp.uint32)
    h = (k * _HASH_MULT) ^ (k >> 16)
    return h * _HASH_MULT


def masked_key(table: Table, key: str) -> jnp.ndarray:
    col = table.columns[key]
    return jnp.where(table.valid_mask(), col, key_sentinel(col.dtype))


def sort_by(table: Table, key: str) -> Table:
    """Stable local sort by key; invalid rows stay at the end."""
    order = jnp.argsort(masked_key(table, key), stable=True)
    cols = {k: v[order] for k, v in table.columns.items()}
    return Table(columns=cols, nrows=table.nrows)


def filter_rows(table: Table, keep: jnp.ndarray) -> Table:
    """Compact rows where keep & valid (stable)."""
    keep = keep & table.valid_mask()
    order = jnp.argsort(~keep, stable=True)  # kept rows first, stable
    cols = {k: v[order] for k, v in table.columns.items()}
    return Table(columns=cols, nrows=jnp.sum(keep).astype(jnp.int32))


def project(table: Table, names) -> Table:
    return Table(columns={k: table.columns[k] for k in names},
                 nrows=table.nrows)


def concat(a: Table, b: Table, capacity: int) -> Table:
    """Concatenate valid rows of a and b into a new padded table."""
    an, bn = a.nrows, b.nrows
    cols = {}
    for k in a.columns:
        va, vb = a.columns[k], b.columns[k]
        buf = jnp.zeros((capacity,) + va.shape[1:], va.dtype)
        buf = jax.lax.dynamic_update_slice_in_dim(buf, va, 0, axis=0)
        # place b's rows starting at a.nrows via scatter
        idx = jnp.arange(vb.shape[0]) + an
        idx = jnp.where(jnp.arange(vb.shape[0]) < bn, idx, capacity)
        buf = buf.at[idx].set(vb, mode="drop")
        cols[k] = buf
    return Table(columns=cols, nrows=(an + bn).astype(jnp.int32))


def join_inner(left: Table, right: Table, key: str, out_capacity: int):
    """Sort-merge inner join with duplicate keys.

    Returns (Table, overflow: bool array).  Non-key columns are prefixed
    l_/r_ on name collision.  Output order: left-key sorted, stable.
    """
    ls = sort_by(left, key)
    rs = sort_by(right, key)
    lk = masked_key(ls, key)
    rk = masked_key(rs, key)
    lo = jnp.searchsorted(rk, lk, side="left")
    hi = jnp.searchsorted(rk, lk, side="right")
    # clamp matches against invalid right rows
    hi = jnp.minimum(hi, rs.nrows)
    lo = jnp.minimum(lo, rs.nrows)
    counts = jnp.where(ls.valid_mask(), hi - lo, 0)
    ends = jnp.cumsum(counts)
    total = ends[-1]
    starts = ends - counts

    out_idx = jnp.arange(out_capacity)
    li = jnp.searchsorted(ends, out_idx, side="right")      # left row of pair j
    li_c = jnp.minimum(li, ls.capacity - 1)
    ri = lo[li_c] + (out_idx - starts[li_c])
    valid_out = out_idx < jnp.minimum(total, out_capacity)
    li_g = jnp.where(valid_out, li_c, 0)
    ri_g = jnp.where(valid_out, jnp.minimum(ri, rs.capacity - 1), 0)

    cols = {}
    for k, v in ls.columns.items():
        name = k if k == key else (f"l_{k}" if k in rs.columns else k)
        cols[name] = jnp.where(
            _expand(valid_out, v.ndim), v[li_g], jnp.zeros_like(v[li_g]))
    for k, v in rs.columns.items():
        if k == key:
            continue
        name = f"r_{k}" if k in ls.columns else k
        cols[name] = jnp.where(
            _expand(valid_out, v.ndim), v[ri_g], jnp.zeros_like(v[ri_g]))
    out = Table(columns=cols,
                nrows=jnp.minimum(total, out_capacity).astype(jnp.int32))
    return out, total > out_capacity


def _expand(mask, ndim):
    return mask.reshape(mask.shape + (1,) * (ndim - 1))


def groupby_sum(table: Table, key: str, value_cols) -> Table:
    """Sum value_cols per key.  Output: unique keys (padded) + sums."""
    ts = sort_by(table, key)
    k = masked_key(ts, key)
    valid = ts.valid_mask()
    is_start = valid & ((jnp.arange(ts.capacity) == 0) | (k != jnp.roll(k, 1)))
    seg_ids = jnp.cumsum(is_start) - 1            # group index per row
    n_groups = jnp.sum(is_start).astype(jnp.int32)
    cap = ts.capacity
    cols = {}
    # representative key per group
    first_pos = jnp.zeros((cap,), jnp.int32).at[
        jnp.where(is_start, seg_ids, cap)].set(jnp.arange(cap), mode="drop")
    cols[key] = jnp.where(jnp.arange(cap) < n_groups,
                          ts.columns[key][first_pos], 0)
    for vc in value_cols:
        v = jnp.where(_expand(valid, ts.columns[vc].ndim), ts.columns[vc], 0)
        seg = jnp.where(valid, seg_ids, cap)
        summed = jnp.zeros((cap,) + v.shape[1:], v.dtype).at[seg].add(
            v, mode="drop")
        cols[vc] = summed
    return Table(columns=cols, nrows=n_groups)
