"""Pure-numpy oracles for the dataframe operators (tests + benchmarks)."""
from __future__ import annotations

import numpy as np


def ref_sort(data: dict, key: str) -> dict:
    order = np.argsort(data[key], kind="stable")
    return {k: np.asarray(v)[order] for k, v in data.items()}


def ref_join_inner(left: dict, right: dict, key: str) -> dict:
    """Inner join with duplicates, left-key-sorted output (matches
    ops_local.join_inner / ops_dist ordering after sorting)."""
    lk, rk = np.asarray(left[key]), np.asarray(right[key])
    r_order = np.argsort(rk, kind="stable")
    rk_s = rk[r_order]
    lo = np.searchsorted(rk_s, lk, side="left")
    hi = np.searchsorted(rk_s, lk, side="right")
    l_idx = np.repeat(np.arange(len(lk)), hi - lo)
    r_idx = np.concatenate([r_order[a:b] for a, b in zip(lo, hi, strict=True)]) \
        if len(lk) else np.zeros((0,), np.int64)
    out = {}
    for k, v in left.items():
        name = k if k == key else (f"l_{k}" if k in right else k)
        out[name] = np.asarray(v)[l_idx]
    for k, v in right.items():
        if k == key:
            continue
        name = f"r_{k}" if k in left else k
        out[name] = np.asarray(v)[r_idx]
    return out


def ref_groupby_sum(data: dict, key: str, value_cols) -> dict:
    keys = np.asarray(data[key])
    uniq, inv = np.unique(keys, return_inverse=True)
    out = {key: uniq}
    for vc in value_cols:
        v = np.asarray(data[vc])
        acc = np.zeros((len(uniq),) + v.shape[1:], v.dtype)
        np.add.at(acc, inv, v)
        out[vc] = acc
    return out


def sorted_rows(data: dict, keys=None) -> np.ndarray:
    """Canonical row ordering for set-equality comparisons."""
    names = keys or sorted(data)
    arr = np.stack([np.asarray(data[n]).astype(np.float64) for n in names], 1)
    order = np.lexsort(arr.T[::-1])
    return arr[order]
