"""Columnar Table: the Cylon/Arrow table abstraction under XLA's static-shape
constraint.  Columns are fixed-capacity padded arrays plus a valid-row count;
every operator preserves the (capacity, nrows) contract and reports overflow
explicitly instead of reallocating.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Table:
    columns: Dict[str, jnp.ndarray]   # each (capacity, ...)
    nrows: jnp.ndarray                # scalar int32

    # --- pytree protocol ---
    def tree_flatten(self):
        names = sorted(self.columns)
        return ([self.columns[n] for n in names] + [self.nrows], names)

    @classmethod
    def tree_unflatten(cls, names, children):
        return cls(columns=dict(zip(names, children[:-1], strict=True)),
                   nrows=children[-1])

    # --- helpers ---
    @property
    def capacity(self) -> int:
        return next(iter(self.columns.values())).shape[0]

    @property
    def column_names(self):
        return sorted(self.columns)

    def valid_mask(self) -> jnp.ndarray:
        return jnp.arange(self.capacity) < self.nrows

    def to_numpy(self) -> dict:
        nrows = np.asarray(self.nrows)
        if nrows.ndim:
            # distributed table: nrows is a per-rank vector and the columns
            # are rank-major (P*capacity,) buffers — int(nrows) would throw
            # an opaque conversion error.  Delegate to collect_table, which
            # strips each rank's padding before concatenating.
            from repro.dataframe.ops_dist import collect_table
            return collect_table(self)
        n = int(nrows)
        return {k: np.asarray(v)[:n] for k, v in self.columns.items()}


def from_numpy(data: dict, capacity: int | None = None) -> Table:
    n = len(next(iter(data.values())))
    cap = capacity or n
    assert cap >= n
    cols = {}
    for k, v in data.items():
        v = np.asarray(v)
        pad = np.zeros((cap - n,) + v.shape[1:], v.dtype)
        cols[k] = jnp.asarray(np.concatenate([v, pad], axis=0))
    return Table(columns=cols, nrows=jnp.asarray(n, jnp.int32))


def empty_like(table: Table, capacity: int) -> Table:
    cols = {k: jnp.zeros((capacity,) + v.shape[1:], v.dtype)
            for k, v in table.columns.items()}
    return Table(columns=cols, nrows=jnp.asarray(0, jnp.int32))


def key_sentinel(dtype) -> jnp.ndarray:
    """Max value used to push invalid rows to the end of sorts."""
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(jnp.iinfo(dtype).max, dtype)
    return jnp.asarray(jnp.finfo(dtype).max, dtype)
