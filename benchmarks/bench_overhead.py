"""Paper Table 2 (overhead column): communicator-construction + task
description overhead vs rank count.

The paper reports 2.3-3.5 s (MPI bootstrap) roughly FLAT from 148 to 518
ranks.  Our JAX analogue builds a sub-mesh (data structure only) — measured
here at the same rank counts on 512 fake host devices — plus the one-time
program lowering cost which is the honest JAX equivalent of the MPI
bootstrap.  The claim checked: overhead is O(1)-ish in ranks (constant-factor
band), matching the paper's flat overhead column.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import ART, ROOT, emit, run_with_devices, trace_summary
from repro.core import SimOptions, TaskDescription, simulate

RANKS = [148, 222, 296, 370, 444, 518]

SNIPPET = r"""
import json, time, statistics
import jax
from repro.core import build_communicator

devices = jax.devices()
out = []
for ranks in %RANKS%:
    builds = []
    for _ in range(5):
        t0 = time.perf_counter()
        comm = build_communicator(devices[:ranks], axes=("df",))
        builds.append(time.perf_counter() - t0)
    # cold overhead: mesh + first trivial lowering on the private mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    import jax.numpy as jnp
    t0 = time.perf_counter()
    f = jax.jit(jax.shard_map(lambda x: jax.lax.psum(x, "df"),
                              mesh=comm.mesh, in_specs=P("df"), out_specs=P()))
    xs = jax.ShapeDtypeStruct((ranks, 8), jnp.float32)
    f.lower(xs).compile()
    cold = time.perf_counter() - t0
    out.append({"ranks": ranks, "build_s": statistics.median(builds),
                "cold_s": cold})
print("RESULT::" + json.dumps(out))
"""


def sim_trace_overhead():
    """Paper Table 2 overhead column via the scheduler's event trace: run one
    task per rank count through the unified core on the virtual clock and
    read the comm_build events back — the same trace schema the live
    executor emits, so overhead accounting is verified end-to-end."""
    rows = []
    for ranks in RANKS:
        rep = simulate([TaskDescription(
            name=f"probe{ranks}", ranks=ranks, fn=None,
            duration_model=lambda r: 1.0, tags={"pipeline": "probe"})],
            ranks, SimOptions(noise=0.0))
        ts = trace_summary(rep)
        rows.append({"ranks": ranks, "overhead_s": ts["comm_build_mean_s"]})
        emit(f"overhead/sim_trace/ranks={ranks}",
             ts["comm_build_mean_s"] * 1e6,
             f"n_dispatch={ts['n_dispatch']}")
    return rows


def _nop(comm):
    return 0


def _dispatch_latencies(report) -> list:
    disp = {e.uid: e.t for e in report.trace if e.kind == "dispatch"}
    return [e.t - disp[e.uid] for e in report.trace
            if e.kind == "done" and e.uid in disp]


def proc_dispatch_overhead(n_tasks: int = 24):
    """Paper §5 'minimal and constant overhead' claim for the MULTI-PROCESS
    pilot: round-trip dispatch->done latency of no-op tasks through
    ProcessExecutor (pickle over the wire, cross-process scheduling) vs the
    in-process ThreadExecutor baseline, at two workload sizes to show the
    per-task cost does not grow with the task count."""
    import statistics

    from repro.core import (ProcessExecutor, ResourceManager,
                            SchedulerSession, ThreadExecutor)

    def descs(n):
        return [TaskDescription(name=f"nop{i}", ranks=1, fn=_nop,
                                tags={"pipeline": "bench"}) for i in range(n)]

    rows = []
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, tick=0.005,
                         extra_pythonpath=[str(ROOT)]) as ex:
        # warm-up: first dispatch per worker pays payload-import costs
        SchedulerSession(ex, ex.resource_manager(),
                         tick=0.005).run(descs(2), timeout=120)
        for n in (max(n_tasks // 3, 4), n_tasks):
            sess = SchedulerSession(ThreadExecutor(build_comm=False,
                                                   tick=0.005),
                                    ResourceManager(["d0", "d1"]), tick=0.005)
            thr = statistics.median(
                _dispatch_latencies(sess.run(descs(n), timeout=120)))
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005)
            prc = statistics.median(
                _dispatch_latencies(sess.run(descs(n), timeout=120)))
            emit(f"overhead/proc_dispatch/n={n}", prc * 1e6,
                 f"thread_us={thr * 1e6:.1f};ratio={prc / max(thr, 1e-9):.1f}")
            rows.append({"n_tasks": n, "proc_us": prc * 1e6,
                         "thread_us": thr * 1e6})
    flat = rows[-1]["proc_us"] / max(rows[0]["proc_us"], 1e-9)
    emit("overhead/proc_dispatch/flatness_ratio", flat * 1e6,
         "paper_claims_constant;per_task_latency_large_over_small")
    return rows


def _placement_hold(comm, dur=0.6):
    import time as _t
    _t.sleep(dur)
    return "held"


def _placement_probe(comm, n_coll=16):
    """A spanning-size payload: n_coll allgathers.  Under pack (one part on
    one worker) they complete locally; under spread (parts straddling
    workers) each is a parent-hub round-trip.  The thread backend's comm has
    no cross-process collectives (one address space) — skipped there."""
    size = getattr(comm, "local_size", comm.size)
    for _ in range(n_coll):
        if hasattr(comm, "allgather"):
            comm.allgather(size)
    return getattr(comm, "hub_calls", 0)


def placement_compare(n_coll: int = 16):
    """Placement policy comparison (the tentpole claim): a task that FITS one
    worker but is dispatched over a fragmented pool.  ``spread`` reproduces
    the historical flat order — the task straddles two workers and pays
    ``n_coll`` hub collectives; ``pack`` places it on a single worker: zero
    hub collectives.  Reported per backend: hub-collective count and the
    probe task's wall time (dispatch->done from the trace)."""
    from repro.core import (ProcessExecutor, ResourceManager,
                            SchedulerSession, TaskDescription, ThreadExecutor)

    def descs():
        return [TaskDescription(name="hold", ranks=1, fn=_placement_hold,
                                tags={"pipeline": "bench"}),
                TaskDescription(name="probe", ranks=2, fn=_placement_probe,
                                kwargs={"n_coll": n_coll},
                                tags={"pipeline": "bench"})]

    def probe_wall(report):
        disp = {e.task: e.t for e in report.trace if e.kind == "dispatch"}
        done = {e.task: e.t for e in report.trace if e.kind == "done"}
        return done["probe"] - disp["probe"]

    rows = []
    for placement in ("spread", "pack"):
        with ProcessExecutor(n_workers=2, devices_per_worker=2,
                             build_comm=False, tick=0.005,
                             extra_pythonpath=[str(ROOT)]) as ex:
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005,
                                    placement=placement)
            rep = sess.run(descs(), timeout=120)
            by = {t.desc.name: t for t in rep.tasks}
            hub = by["probe"].result
            wall = probe_wall(rep)
        emit(f"placement/proc/{placement}", wall * 1e6,
             f"hub_collectives={hub};n_coll={n_coll}")
        rows.append({"backend": "proc", "placement": placement,
                     "hub_collectives": hub, "wall_s": wall})
    for placement in ("spread", "pack"):
        # thread backend: one address space, so placement cannot change the
        # collective count (always 0 hub trips) — the baseline that shows
        # the win is specific to the multi-process topology
        sess = SchedulerSession(ThreadExecutor(build_comm=False, tick=0.005),
                                ResourceManager([f"d{i}" for i in range(4)]),
                                tick=0.005, placement=placement)
        rep = sess.run(descs(), timeout=120)
        wall = probe_wall(rep)
        emit(f"placement/thread/{placement}", wall * 1e6,
             "hub_collectives=0")
        rows.append({"backend": "thread", "placement": placement,
                     "hub_collectives": 0, "wall_s": wall})
    return rows


def elastic_grow_latency():
    """Elastic pilot smoke (BENCH_ELASTIC=1): how quickly pending work runs
    after an elastic grow.  A 2-rank task is submitted against a 1-device
    pilot (infeasible), then ``add_worker`` spawns a second worker at
    runtime.  Reported from the ONE TraceEvent stream: time-to-first-
    dispatch measured from add_worker() returning (the paper-facing number:
    includes only scheduler absorption, the interpreter spawn already
    happened inside add_worker) and the add_worker wall time itself (the
    full cost of acquiring a node mid-run).  Rows land in
    ``benchmarks/artifacts/elastic_summary.json`` (the CI artifact)."""
    import time as _t

    from repro.core import ProcessExecutor, SchedulerSession

    with ProcessExecutor(n_workers=1, devices_per_worker=1,
                         build_comm=False, tick=0.005,
                         extra_pythonpath=[str(ROOT)]) as ex:
        sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005)
        # warm-up: the first dispatch pays payload-import costs
        sess.run([TaskDescription(name="warm", ranks=1, fn=_nop,
                                  tags={"pipeline": "bench"})], timeout=120)
        sess.submit([TaskDescription(name="wide", ranks=2, fn=_nop,
                                     tags={"pipeline": "bench"})])
        t0 = _t.perf_counter()
        ex.add_worker(devices_per_worker=1)
        t_added = _t.perf_counter()        # same clock as executor.now()
        sess.drain(timeout=120)
        rep = sess.close()
        ts = trace_summary(rep)
    grow_t = next(e.t for e in rep.trace if e.kind == "grow")
    disp_t = next(e.t for e in rep.trace
                  if e.kind == "dispatch" and e.task == "wide")
    row = {
        "add_worker_wall_s": t_added - t0,
        "grow_to_dispatch_s": disp_t - grow_t,
        "added_to_dispatch_s": disp_t - t_added,
        "trace_summary": ts,
    }
    assert ts["n_grow"] == 1 and ts["n_dispatch"] == 2
    emit("elastic/add_worker_wall", row["add_worker_wall_s"] * 1e6,
         "interpreter spawn + HELLO + address-book push")
    emit("elastic/time_to_first_dispatch", row["added_to_dispatch_s"] * 1e6,
         f"grow_to_dispatch_us={row['grow_to_dispatch_s'] * 1e6:.1f}")
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "elastic_summary.json").write_text(
        json.dumps(row, indent=2, default=str))
    return row


def _p2p_probe(comm, n_coll=6, nbytes=4 << 20):
    """A join/sort-shaped exchange: every part allgathers a large blob
    ``n_coll`` times (the paper's spanning intermediates), then reports the
    comm counters so the trace evidence can be cross-checked."""
    blob = bytes([comm.part]) * nbytes
    for _ in range(n_coll):
        vals = comm.allgather(blob)
        assert all(len(v) == nbytes for v in vals)
    return {"p2p_bytes": comm.p2p_bytes, "hub_calls": comm.hub_calls,
            "fallbacks": comm.p2p_fallbacks}


def p2p_compare(n_coll: int = 6, nbytes: int = 4 << 20):
    """Data-plane comparison (the tentpole claim): the SAME large-payload
    spanning allgather, once with the peer plane disabled (every byte relays
    through the parent hub — two socket hops per payload plus a central
    bottleneck) and once enabled (payloads move worker-to-worker; the hub
    keeps only the tiny per-collective control frame).  Reports wall time of
    the probe task (dispatch->done from the trace), bytes by path, and the
    uniform trace_summary fields; the rows are also written to
    ``benchmarks/artifacts/p2p_summary.json`` (the CI artifact)."""
    from repro.core import ProcessExecutor, SchedulerSession

    rows = []
    for p2p in (False, True):
        with ProcessExecutor(n_workers=2, devices_per_worker=1,
                             build_comm=False, tick=0.005, p2p=p2p,
                             extra_pythonpath=[str(ROOT)]) as ex:
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005)
            # warm-up: pay worker-side payload-import cost outside the probe
            sess.run([TaskDescription(name="warm", ranks=2, fn=_p2p_probe,
                                      kwargs={"n_coll": 1, "nbytes": 1 << 14},
                                      tags={"pipeline": "bench"})],
                     timeout=120)
            rep = sess.run([TaskDescription(
                name="probe", ranks=2, fn=_p2p_probe,
                kwargs={"n_coll": n_coll, "nbytes": nbytes},
                tags={"pipeline": "bench"})], timeout=300)
            by = {t.desc.name: t for t in rep.tasks}
            probe = by["probe"]
            disp = {e.task: e.t for e in rep.trace if e.kind == "dispatch"}
            done = {e.task: e.t for e in rep.trace if e.kind == "done"}
            wall = done["probe"] - disp["probe"]
            ts = trace_summary(rep)
            rows.append({
                "mode": "peer" if p2p else "hub-relay",
                "n_coll": n_coll, "nbytes": nbytes, "wall_s": wall,
                "p2p_bytes": probe.p2p_bytes,
                "hub_relay_bytes": ex.hub_relay_bytes,
                "hub_calls": probe.hub_calls,
                "fallbacks": probe.result["fallbacks"],
                "trace_summary": ts,
            })
        emit(f"p2p/allgather/{rows[-1]['mode']}", wall * 1e6,
             f"p2p_bytes={probe.p2p_bytes};"
             f"hub_relay_bytes={rows[-1]['hub_relay_bytes']};"
             f"n_coll={n_coll};nbytes={nbytes}")
    speedup = rows[0]["wall_s"] / max(rows[1]["wall_s"], 1e-9)
    emit("p2p/allgather/speedup_hub_over_peer", speedup * 1e6,
         "wall_hub/wall_peer;>1 means the peer plane wins")
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "p2p_summary.json").write_text(
        json.dumps({"rows": rows, "speedup_hub_over_peer": speedup},
                   indent=2, default=str))
    return rows


def _xport_probe(comm, n_coll=4, nbytes=1 << 20):
    """The transport-tier probe: every part allgathers an ``nbytes`` float64
    array ``n_coll`` times.  The SAME payload runs under every tier knob
    combination — pickled baseline included — so walls are comparable, and
    the comm counters come back for the telemetry cross-check."""
    import numpy as np
    m = np.full((nbytes // 8,), float(comm.part), dtype=np.float64)
    for _ in range(n_coll):
        vals = comm.allgather(m)
        assert len(vals) == comm.n_parts
    return {"p2p_bytes": comm.p2p_bytes, "raw": comm.raw_coll_bytes,
            "shm": comm.shm_bytes, "ring": comm.ring_steps,
            "fallbacks": comm.p2p_fallbacks, "hub_calls": comm.hub_calls}


# {pickled vs raw} x {direct vs ring} x {tcp vs shm}; ring legs only make
# sense at >= RING_MIN_PARTS so the 2-worker grid drops them (ring falls
# back to direct below 4 parts by design)
TRANSPORT_GRID = [
    ("pickled-direct-tcp", {"raw_frames": False, "ring": False,
                            "shm": False}),
    ("raw-direct-tcp", {"ring": False, "shm": False}),
    ("raw-direct-shm", {"ring": False, "shm": True}),
    ("raw-ring-tcp", {"ring": True, "shm": False}),
    ("raw-ring-shm", {"ring": True, "shm": True}),
]
TRANSPORT_SIZES = {64 << 10: 12, 1 << 20: 8, 8 << 20: 3}   # nbytes -> n_coll


def transport_compare():
    """Transport-tier A/B (BENCH_TRANSPORT=1): the same wide allgather
    workload across the tier grid — zero-copy raw framing vs pickle, ring
    vs direct fan-out, same-host shm handoff vs TCP — at 64 KiB / 1 MiB /
    8 MiB payloads on 2 and 4 workers.  Walls are dispatch->done from the
    trace; every row carries both the comm-reported counters (task result)
    and the trace-derived ones, asserted equal against the executor's
    running totals (the telemetry cross-check).  Acceptance keys in
    ``benchmarks/artifacts/transport_summary.json``: the wide (4-part)
    >= 1 MiB allgather beats the direct-pickled baseline by >= 1.5x, and
    shm beats tcp at >= 1 MiB."""
    from repro.core import ProcessExecutor, SchedulerSession

    rows = []
    for workers in (2, 4):
        for config, kw in TRANSPORT_GRID:
            if workers < 4 and kw.get("ring"):
                continue
            with ProcessExecutor(n_workers=workers, devices_per_worker=1,
                                 build_comm=False, tick=0.005, **kw,
                                 extra_pythonpath=[str(ROOT)]) as ex:
                # warm-up: payload-import cost + first peer channels
                SchedulerSession(ex, ex.resource_manager(), tick=0.005).run(
                    [TaskDescription(
                        name="warm", ranks=workers, fn=_xport_probe,
                        kwargs={"n_coll": 1, "nbytes": 1 << 14},
                        tags={"pipeline": "bench"})], timeout=120)
                for nbytes, n_coll in TRANSPORT_SIZES.items():
                    before = (ex.raw_coll_bytes, ex.shm_bytes, ex.ring_steps)
                    # fresh session per probe: its report then covers exactly
                    # this probe's tasks, making the counter deltas exact
                    sess = SchedulerSession(ex, ex.resource_manager(),
                                            tick=0.005)
                    rep = sess.run([TaskDescription(
                        name="probe", ranks=workers, fn=_xport_probe,
                        kwargs={"n_coll": n_coll, "nbytes": nbytes},
                        tags={"pipeline": "bench"})], timeout=300)
                    probe = rep.tasks[0]
                    disp = {e.task: e.t for e in rep.trace
                            if e.kind == "dispatch"}
                    done = {e.task: e.t for e in rep.trace
                            if e.kind == "done"}
                    wall = done["probe"] - disp["probe"]
                    ts = trace_summary(rep)
                    # telemetry cross-check: the trace-derived counters must
                    # equal what the executor accumulated for this session
                    assert ts["raw_coll_bytes"] == \
                        ex.raw_coll_bytes - before[0]
                    assert ts["shm_bytes"] == ex.shm_bytes - before[1]
                    assert ts["ring_steps"] == ex.ring_steps - before[2]
                    assert probe.result["fallbacks"] == 0
                    rows.append({
                        "workers": workers, "config": config,
                        "nbytes": nbytes, "n_coll": n_coll, "wall_s": wall,
                        "us_per_coll": wall / n_coll * 1e6,
                        "p2p_bytes": probe.p2p_bytes,
                        "raw_coll_bytes": probe.raw_coll_bytes,
                        "shm_bytes": probe.shm_bytes,
                        "ring_steps": probe.ring_steps,
                        "hub_calls": probe.hub_calls,
                        "trace_summary": ts,
                    })
                    emit(f"transport/{workers}w/{config}/nbytes={nbytes}",
                         wall / n_coll * 1e6,
                         f"shm_bytes={probe.shm_bytes};"
                         f"ring_steps={probe.ring_steps};"
                         f"raw_coll_bytes={probe.raw_coll_bytes}")

    def wall(workers, config, nbytes):
        return next(r["wall_s"] for r in rows
                    if r["workers"] == workers and r["config"] == config
                    and r["nbytes"] == nbytes)

    # acceptance: wide (4-part, >= 1 MiB) vs the direct-pickled baseline,
    # best tiered config wins the comparison
    tiered = [c for c, _ in TRANSPORT_GRID if c != "pickled-direct-tcp"]
    speedup_wide = {}
    for nbytes in TRANSPORT_SIZES:
        base = wall(4, "pickled-direct-tcp", nbytes)
        best_c = min(tiered, key=lambda c, n=nbytes: wall(4, c, n))
        speedup_wide[str(nbytes)] = {
            "speedup": base / max(wall(4, best_c, nbytes), 1e-9),
            "best_config": best_c}
        emit(f"transport/4w/speedup_vs_pickled/nbytes={nbytes}",
             speedup_wide[str(nbytes)]["speedup"] * 1e6,
             f"best={best_c};acceptance_bar=1.5_at_1MiB")
    # acceptance: shm vs tcp on the same-host pair, raw framing held equal
    shm_vs_tcp = {str(n): wall(2, "raw-direct-tcp", n) /
                  max(wall(2, "raw-direct-shm", n), 1e-9)
                  for n in TRANSPORT_SIZES}
    for n, s in shm_vs_tcp.items():
        emit(f"transport/2w/shm_over_tcp/nbytes={n}", s * 1e6,
             "wall_tcp/wall_shm;>1 means shm wins;acceptance_bar=1.0_at_1MiB")
    out = {"rows": rows, "speedup_wide_4p": speedup_wide,
           "shm_over_tcp_2p": shm_vs_tcp,
           "acceptance": {"wide_1mib_min_speedup": 1.5,
                          "shm_beats_tcp_at": 1 << 20}}
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "transport_summary.json").write_text(
        json.dumps(out, indent=2, default=str))
    return out


def _trace_probe(comm, n_coll=8, compute_s=0.02):
    # collective-heavy part with a realistic compute phase: the span volume
    # (launch/deserialize/compute + one wait span per hub round-trip) is what
    # the recorder pays for, the compute is what any real task amortizes it
    # against — a pure-collective probe would measure JSONL cost against an
    # empty denominator
    import time as _t
    for _ in range(n_coll):
        if hasattr(comm, "allgather"):
            comm.allgather(b"x" * 2048)
    _t.sleep(compute_s)
    return 0


def trace_overhead(n_tasks: int = 12, repeats: int = 3):
    """Flight-recorder cost (BENCH_TRACE=1): the SAME spanning workload run
    with tracing off and with tracing on (spans + telemetry + JSONL
    streaming), medians over ``repeats``.  The recorder's contract is
    "cheap enough to leave on" — the acceptance bar is < 5% wall-time
    overhead, recorded alongside the measurements in
    ``benchmarks/artifacts/trace_overhead.json`` (the CI artifact)."""
    import statistics
    import tempfile

    from repro.core import ProcessExecutor, SchedulerSession

    def descs():
        return [TaskDescription(name=f"probe{i}", ranks=2, fn=_trace_probe,
                                tags={"pipeline": "bench"})
                for i in range(n_tasks)]

    rows = []
    with ProcessExecutor(n_workers=2, devices_per_worker=1,
                         build_comm=False, tick=0.005,
                         extra_pythonpath=[str(ROOT)]) as ex:
        # warm-up: first dispatch per worker pays payload-import costs
        SchedulerSession(ex, ex.resource_manager(),
                         tick=0.005).run(descs()[:2], timeout=120)
        tmp = tempfile.mkdtemp(prefix="repro-trace-bench-")
        for mode, trace_path in (("off", None),
                                 ("on", os.path.join(tmp, "bench.jsonl"))):
            walls = []
            for _ in range(repeats):
                sess = SchedulerSession(ex, ex.resource_manager(),
                                        tick=0.005, trace_path=trace_path)
                rep = sess.run(descs(), timeout=120)
                walls.append(rep.makespan)
            rows.append({"mode": mode, "wall_s": statistics.median(walls),
                         "n_tasks": n_tasks,
                         "n_spans": len(rep.spans),
                         "n_telemetry": len(rep.telemetry)})
    overhead = rows[1]["wall_s"] / max(rows[0]["wall_s"], 1e-9) - 1.0
    for r in rows:
        emit(f"trace/{r['mode']}", r["wall_s"] * 1e6,
             f"n_spans={r['n_spans']};n_telemetry={r['n_telemetry']}")
    emit("trace/overhead_frac", overhead * 1e6,
         "acceptance_bar=0.05;wall_on/wall_off-1")
    out = {"rows": rows, "overhead_frac": overhead, "acceptance_bar": 0.05}
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "trace_overhead.json").write_text(
        json.dumps(out, indent=2, default=str))
    return out


def _poisson_arrivals(n: int, mean_gap_s: float, seed: int = 7):
    """Open-loop Poisson arrival offsets: exponential inter-arrival gaps,
    cumulative from t=0.  Open-loop means the schedule never waits for the
    server — a slow server accumulates backlog instead of slowing arrivals,
    which is what makes the latency percentiles honest."""
    import numpy as np
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(mean_gap_s, n))


def serve_compare(n_requests: int = 64, mean_gap_s: float = 0.0005):
    """Continuous batching vs the static-batch baseline (BENCH_SERVE=1): the
    SAME open-loop Poisson request stream — mixed prompt lengths {3, 5},
    mixed budgets (1 in 4 requests wants 24 tokens, the rest want 2) — served
    by both engines over the same model/params.  The static engine groups by
    prompt length and decodes every group to its LONGEST member before
    draining; the continuous engine frees a slot the moment a request
    finishes and admits mid-decode, so short requests stop paying for long
    neighbours.  Reported per mode: req/s and p50/p99 request latency
    (finish wall - arrival wall); outputs are asserted bit-identical across
    engines.  Acceptance key in ``benchmarks/artifacts/serve_summary.json``:
    continuous >= 1.3x static throughput."""
    import dataclasses
    import time as _t

    import jax
    import numpy as np

    from repro.configs import get_config, reduced
    from repro.models import get_model
    from repro.serve import ContinuousEngine, Request, ServeEngine

    cfg = dataclasses.replace(reduced(get_config("granite-3-8b")), n_layers=2)
    api = get_model(cfg)
    params = api.init(jax.random.key(0), cfg)
    max_batch, max_seq = 4, 64
    rng = np.random.default_rng(1)
    plens = rng.choice([3, 5], n_requests)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, int(L))
                    .astype(np.int32),
                    max_new_tokens=(24 if i % 4 == 0 else 2), uid=i)
            for i, L in enumerate(plens)]
    arrivals = _poisson_arrivals(n_requests, mean_gap_s)

    eng_s = ServeEngine(cfg, params, max_batch=max_batch, max_seq=max_seq)
    eng_c = ContinuousEngine(cfg, params, max_batch=max_batch,
                             max_seq=max_seq)
    # warm-up: compile every shape either engine can hit, so the measured
    # loops pay dispatch cost only.  Static compiles per (batch, prompt_len)
    # prefill and per batch-width decode; continuous compiles exactly one
    # prefill per prompt_len (batch 1), one decode, one insert.
    for plen in (3, 5):
        for b in range(1, max_batch + 1):
            eng_s._run_batch([Request(prompt=np.zeros(plen, np.int32),
                                      max_new_tokens=1, uid=-1)] * b)
        eng_c.run([Request(prompt=np.zeros(plen, np.int32),
                           max_new_tokens=2, uid=-1)])
    eng_c.results.clear()
    eng_c.evicted.clear()

    def run_static():
        latency, outputs, backlog, i = {}, {}, [], 0
        t0 = _t.perf_counter()
        while len(latency) < n_requests:
            now = _t.perf_counter() - t0
            while i < n_requests and arrivals[i] <= now:
                backlog.append(reqs[i])
                i += 1
            if not backlog:
                _t.sleep(max(arrivals[i] - now, 0.0))
                continue
            # static admission: the largest same-prompt-length group that has
            # arrived (causal prefill forbids mixing lengths), up to max_batch
            by_len: dict[int, list] = {}
            for r in backlog:
                by_len.setdefault(len(r.prompt), []).append(r)
            group = max(by_len.values(), key=len)[:max_batch]
            taken = {id(r) for r in group}
            backlog = [r for r in backlog if id(r) not in taken]
            out = eng_s._run_batch(group)
            tdone = _t.perf_counter() - t0
            outputs.update(out)
            for uid in out:
                latency[uid] = tdone - arrivals[uid]
        return latency, outputs, _t.perf_counter() - t0

    def run_continuous():
        latency, i = {}, 0
        t0 = _t.perf_counter()
        while len(latency) < n_requests:
            now = _t.perf_counter() - t0
            while i < n_requests and arrivals[i] <= now:
                eng_c.submit(reqs[i])
                i += 1
            if eng_c.outstanding == 0:
                _t.sleep(max(arrivals[i] - now, 0.0))
                continue
            for r in eng_c.step():
                latency[r.uid] = (_t.perf_counter() - t0) - arrivals[r.uid]
        return latency, dict(eng_c.results), _t.perf_counter() - t0

    rows = []
    results = {}
    for mode, runner in (("static", run_static),
                         ("continuous", run_continuous)):
        latency, outputs, wall = runner()
        results[mode] = outputs
        lats = sorted(latency.values())
        row = {"mode": mode, "wall_s": wall,
               "req_per_s": n_requests / wall,
               "p50_latency_s": lats[len(lats) // 2],
               "p99_latency_s": lats[min(int(len(lats) * 0.99),
                                         len(lats) - 1)]}
        rows.append(row)
        emit(f"serve/{mode}/req_per_s", row["req_per_s"] * 1e6,
             f"p50_s={row['p50_latency_s']:.4f};"
             f"p99_s={row['p99_latency_s']:.4f};n={n_requests}")
    # the two engines must agree token-for-token before throughput means
    # anything
    for r in reqs:
        np.testing.assert_array_equal(results["static"][r.uid],
                                      results["continuous"][r.uid])
    speedup = rows[1]["req_per_s"] / max(rows[0]["req_per_s"], 1e-9)
    emit("serve/speedup_continuous_over_static", speedup * 1e6,
         "req_per_s ratio;acceptance_bar=1.3")
    out = {"model": "granite-3-8b reduced n_layers=2",
           "n_requests": n_requests, "max_batch": max_batch,
           "max_seq": max_seq, "arrival_mean_gap_s": mean_gap_s,
           "rows": rows, "speedup_continuous_over_static": speedup,
           "acceptance": {"min_speedup": 1.3, "meets_bar": speedup >= 1.3}}
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "serve_summary.json").write_text(
        json.dumps(out, indent=2, default=str))
    assert speedup >= 1.3, f"continuous vs static speedup {speedup:.2f} < 1.3"
    return out


def _ckpt_steps(comm, n_steps=8, step_s=0.25):
    """N sleep-per-step "training" steps, each durably checkpointed; resumes
    from ``comm.checkpoint`` when the runtime bound one (REPRO_CKPT_DIR)."""
    import time as _t

    import numpy as np

    ck = getattr(comm, "checkpoint", None)
    state = {"acc": np.zeros(4)}
    start = 0
    if ck is not None:
        last = ck.latest()
        if last is not None:
            state = ck.restore(last, like=state)
            start = last + 1
    executed = 0
    for step in range(start, n_steps):
        _t.sleep(step_s)
        state = {"acc": state["acc"] + 1.0}
        if ck is not None:
            ck.save(step, state)
        executed += 1
    return {"executed": executed, "start": start,
            "acc": [float(x) for x in state["acc"]]}


def _cache_sleep(comm, dur=0.2, tag=0):
    import time as _t
    _t.sleep(dur)
    return tag * 2


def ckpt_resume_compare(n_steps: int = 8, step_s: float = 0.25):
    """Crash-safe resume A/B (the PR 10 tentpole claim): a ProcessExecutor
    task is SIGKILLed mid-run after several durably checkpointed steps; the
    retry either resumes from the last completed step (session ckpt_root
    set) or re-runs from scratch.  Reported per mode: steps the recovery
    attempt re-executed, resumed_from_step evidence from the trace, and
    wall.  A result-cache section runs the same task list twice through one
    cache dir and reports the second run's cache_hits.  Everything lands in
    ``benchmarks/artifacts/ckpt_summary.json``."""
    import signal
    import tempfile
    import time as _t

    from repro.core import (ProcessExecutor, ResourceManager,
                            SchedulerSession, ThreadExecutor)

    def run_once(ckpt_root):
        with ProcessExecutor(n_workers=2, devices_per_worker=1,
                             build_comm=False, tick=0.005,
                             heartbeat_interval=0.2,
                             extra_pythonpath=[str(ROOT)]) as ex:
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005,
                                    ckpt_root=ckpt_root)
            t0 = _t.perf_counter()
            (task,) = sess.submit([TaskDescription(
                name="steps", ranks=1, fn=_ckpt_steps,
                kwargs={"n_steps": n_steps, "step_s": step_s},
                tags={"pipeline": "bench"})])
            # let roughly half the steps commit durably, then kill the
            # hosting worker mid-task
            _t.sleep(step_s * (n_steps // 2) + 0.5)
            ex.kill_worker(task.devices[0].worker, signal.SIGKILL)
            rep = sess.drain(timeout=180).close()
            wall = _t.perf_counter() - t0
        steps = next(t for t in rep.tasks if t.desc.name == "steps")
        assert steps.state.value == "DONE", steps.error
        res = steps.result
        ts = trace_summary(rep)
        return {"wall_s": wall, "reexecuted_steps": res["executed"],
                "resumed_from_step": steps.resumed_from_step,
                "n_resume": ts["n_resume"], "n_retry": ts["n_retry"],
                "final_acc": res["acc"][0]}

    with tempfile.TemporaryDirectory() as root:
        with_resume = run_once(os.path.join(root, "ckpt"))
    without_resume = run_once(None)
    for mode, row in (("with_resume", with_resume),
                      ("without_resume", without_resume)):
        emit(f"ckpt/{mode}/reexecuted_steps", row["reexecuted_steps"] * 1e6,
             f"wall_s={row['wall_s']:.2f};"
             f"resumed_from_step={row['resumed_from_step']}")

    # result cache: the same task list twice through one cache dir — the
    # second run completes from disk without dispatching
    with tempfile.TemporaryDirectory() as cache:
        def cache_run():
            sess = SchedulerSession(
                ThreadExecutor(build_comm=False, tick=0.005),
                ResourceManager(["d0", "d1"]), tick=0.005,
                result_cache=cache)
            t0 = _t.perf_counter()
            rep = sess.run([TaskDescription(
                name=f"c{i}", ranks=1, fn=_cache_sleep,
                kwargs={"dur": 0.2, "tag": i},
                tags={"pipeline": "bench"}) for i in range(3)], timeout=60)
            return trace_summary(rep), _t.perf_counter() - t0
        cold, cold_wall = cache_run()
        warm, warm_wall = cache_run()
    emit("ckpt/cache/second_run_hits", warm["cache_hits"] * 1e6,
         f"cold_wall_s={cold_wall:.2f};warm_wall_s={warm_wall:.2f}")

    out = {"n_steps": n_steps, "step_s": step_s,
           "with_resume": with_resume, "without_resume": without_resume,
           "cache": {"cold_wall_s": cold_wall, "warm_wall_s": warm_wall,
                     "cold_hits": cold["cache_hits"],
                     "warm_hits": warm["cache_hits"]},
           "acceptance": {
               "resumed_from_step_positive":
                   with_resume["resumed_from_step"] > 0,
               "fewer_reexecuted_steps":
                   with_resume["reexecuted_steps"]
                   < without_resume["reexecuted_steps"],
               "warm_run_all_hits": warm["cache_hits"] == 3}}
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "ckpt_summary.json").write_text(json.dumps(out, indent=2))
    assert all(out["acceptance"].values()), out["acceptance"]
    return out


def run():
    res = {}
    if os.environ.get("BENCH_REAL", "1") == "1":
        # the 544-fake-device mesh-build section; skippable (BENCH_REAL=0)
        # so CI smokes can run the cheap sections alone
        out = run_with_devices(SNIPPET.replace("%RANKS%", str(RANKS)), 544,
                               timeout=900)  # 544 > 518 max paper rank count
        data = json.loads(out.split("RESULT::")[1])
        builds = [d["build_s"] for d in data]
        for d in data:
            emit(f"overhead/comm_build/ranks={d['ranks']}",
                 d["build_s"] * 1e6, f"cold_lower_s={d['cold_s']:.3f}")
        flat = max(builds) / max(min(builds), 1e-9)
        emit("overhead/flatness_ratio", flat * 1e6,
             "paper_claims_constant;ratio_max_over_min")
        res["real"] = data
    res["sim_trace"] = sim_trace_overhead()
    if os.environ.get("BENCH_PROC", "0") == "1" or "--proc" in sys.argv:
        # opt-in: spawns worker interpreters, adds ~5s to the section
        res["proc_dispatch"] = proc_dispatch_overhead()
    if os.environ.get("BENCH_PLACEMENT", "0") == "1" or \
            "--placement" in sys.argv:
        # opt-in: pack-vs-spread for a spanning-size task (worker processes)
        res["placement"] = placement_compare()
    if os.environ.get("BENCH_P2P", "0") == "1" or "--p2p" in sys.argv:
        # opt-in: peer data plane vs hub relay for large spanning payloads
        res["p2p"] = p2p_compare()
    if os.environ.get("BENCH_TRANSPORT", "0") == "1" or \
            "--transport" in sys.argv:
        # opt-in: tier grid A/B — raw framing / ring / shm vs the pickled
        # direct-TCP baseline at three payload sizes on 2 and 4 workers
        res["transport"] = transport_compare()
    if os.environ.get("BENCH_ELASTIC", "0") == "1" or "--elastic" in sys.argv:
        # opt-in: runtime add_worker -> time-to-first-dispatch for pending
        # work that could not fit the initial inventory
        res["elastic"] = elastic_grow_latency()
    if os.environ.get("BENCH_TRACE", "0") == "1" or "--trace" in sys.argv:
        # opt-in: flight-recorder on/off A/B (spans + telemetry + JSONL)
        res["trace"] = trace_overhead()
    if os.environ.get("BENCH_SERVE", "0") == "1" or "--serve" in sys.argv:
        # opt-in: continuous batching vs static batch on the same Poisson
        # request stream (req/s + latency percentiles)
        res["serve"] = serve_compare()
    if os.environ.get("BENCH_CKPT", "0") == "1" or "--ckpt" in sys.argv:
        # opt-in: checkpoint-resume A/B under a mid-task SIGKILL, plus the
        # result cache's repeated-run hit rate
        res["ckpt"] = ckpt_resume_compare()
    return res


if __name__ == "__main__":
    run()
