"""Paper Table 2 (overhead column): communicator-construction + task
description overhead vs rank count.

The paper reports 2.3-3.5 s (MPI bootstrap) roughly FLAT from 148 to 518
ranks.  Our JAX analogue builds a sub-mesh (data structure only) — measured
here at the same rank counts on 512 fake host devices — plus the one-time
program lowering cost which is the honest JAX equivalent of the MPI
bootstrap.  The claim checked: overhead is O(1)-ish in ranks (constant-factor
band), matching the paper's flat overhead column.
"""
from __future__ import annotations

import json

from benchmarks.common import emit, run_with_devices, trace_summary
from repro.core import SimOptions, TaskDescription, simulate

RANKS = [148, 222, 296, 370, 444, 518]

SNIPPET = r"""
import json, time, statistics
import jax
from repro.core import build_communicator

devices = jax.devices()
out = []
for ranks in %RANKS%:
    builds = []
    for _ in range(5):
        t0 = time.perf_counter()
        comm = build_communicator(devices[:ranks], axes=("df",))
        builds.append(time.perf_counter() - t0)
    # cold overhead: mesh + first trivial lowering on the private mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    import jax.numpy as jnp
    t0 = time.perf_counter()
    f = jax.jit(jax.shard_map(lambda x: jax.lax.psum(x, "df"),
                              mesh=comm.mesh, in_specs=P("df"), out_specs=P()))
    xs = jax.ShapeDtypeStruct((ranks, 8), jnp.float32)
    f.lower(xs).compile()
    cold = time.perf_counter() - t0
    out.append({"ranks": ranks, "build_s": statistics.median(builds),
                "cold_s": cold})
print("RESULT::" + json.dumps(out))
"""


def sim_trace_overhead():
    """Paper Table 2 overhead column via the scheduler's event trace: run one
    task per rank count through the unified core on the virtual clock and
    read the comm_build events back — the same trace schema the live
    executor emits, so overhead accounting is verified end-to-end."""
    rows = []
    for ranks in RANKS:
        rep = simulate([TaskDescription(
            name=f"probe{ranks}", ranks=ranks, fn=None,
            duration_model=lambda r: 1.0, tags={"pipeline": "probe"})],
            ranks, SimOptions(noise=0.0))
        ts = trace_summary(rep)
        rows.append({"ranks": ranks, "overhead_s": ts["comm_build_mean_s"]})
        emit(f"overhead/sim_trace/ranks={ranks}",
             ts["comm_build_mean_s"] * 1e6,
             f"n_dispatch={ts['n_dispatch']}")
    return rows


def run():
    out = run_with_devices(SNIPPET.replace("%RANKS%", str(RANKS)), 544,
                           timeout=900)  # 544 > 518 max paper rank count
    data = json.loads(out.split("RESULT::")[1])
    builds = [d["build_s"] for d in data]
    for d in data:
        emit(f"overhead/comm_build/ranks={d['ranks']}", d["build_s"] * 1e6,
             f"cold_lower_s={d['cold_s']:.3f}")
    flat = max(builds) / max(min(builds), 1e-9)
    emit("overhead/flatness_ratio", flat * 1e6,
         "paper_claims_constant;ratio_max_over_min")
    return {"real": data, "sim_trace": sim_trace_overhead()}


if __name__ == "__main__":
    run()
