"""Kernel hot-spot microbenchmarks.

Pallas kernels target TPU; on this CPU container we (a) time the compiled
pure-jnp reference paths (the mathematical spec each kernel implements) and
(b) count kernel-tile FLOPs/bytes to report the VMEM-resident arithmetic
intensity the TPU kernel achieves by construction.  Kernel *correctness* is
covered by tests/test_kernels.py (interpret mode vs ref).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import FAST, emit, time_call


def bench_attention_ref():
    from repro.models.attention import attend_blockwise
    b, s, h, kh, hd = (1, 1024, 8, 2, 64) if FAST else (2, 4096, 16, 4, 128)
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kh, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kh, hd), jnp.float32)
    f = jax.jit(lambda q, k, v: attend_blockwise(q, k, v, causal=True,
                                                 q_block=256, kv_block=256))
    f(q, k, v).block_until_ready()
    t = time_call(lambda: f(q, k, v).block_until_ready(), iters=3)
    flops = 4 * b * h * s * s * hd  # 2 matmuls x 2 (MAC)
    emit("kernels/flash_attention/jnp_ref", t * 1e6,
         f"gflops_s={flops / t / 1e9:.1f};vmem_tile_bytes="
         f"{(128 * hd * 2 + 128 * 128 * 4) * 2}")


def bench_ssm_ref():
    from repro.kernels.ssm_scan.ref import ssm_scan_ref
    B, S, D, N = (1, 512, 256, 16) if FAST else (2, 2048, 1024, 16)
    ks = jax.random.split(jax.random.key(1), 5)
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, S, D)))
    A = -jnp.exp(jax.random.normal(ks[1], (D, N)) * 0.3)
    Bm = jax.random.normal(ks[2], (B, S, N))
    Cm = jax.random.normal(ks[3], (B, S, N))
    x = jax.random.normal(ks[4], (B, S, D))
    f = jax.jit(ssm_scan_ref)
    f(dt, A, Bm, Cm, x).block_until_ready()
    t = time_call(lambda: f(dt, A, Bm, Cm, x).block_until_ready(), iters=3)
    flops = 6 * B * S * D * N
    emit("kernels/ssm_scan/jnp_ref", t * 1e6,
         f"gflops_s={flops / t / 1e9:.2f};state_bytes_vmem={D * N * 4}")


def bench_sort_ref():
    n = 1 << (14 if FAST else 18)
    keys = jax.random.randint(jax.random.key(2), (4, n), 0, 1 << 30, jnp.int32)
    f = jax.jit(lambda k: jnp.sort(k, axis=-1))
    f(keys).block_until_ready()
    t = time_call(lambda: f(keys).block_until_ready(), iters=3)
    emit("kernels/bitonic_sort/jnp_ref", t * 1e6,
         f"mrows_s={4 * n / t / 1e6:.1f}")


def bench_partition_ref():
    from repro.kernels.radix_partition.ref import destinations_ref
    n, buckets = (1 << 14, 64) if FAST else (1 << 18, 256)
    b = jax.random.randint(jax.random.key(3), (n,), 0, buckets, jnp.int32)
    f = jax.jit(lambda x: destinations_ref(x, buckets))
    jax.block_until_ready(f(b))
    t = time_call(lambda: jax.block_until_ready(f(b)), iters=3)
    emit("kernels/radix_partition/jnp_ref", t * 1e6,
         f"mrows_s={n / t / 1e6:.1f};mxu_onehot_matmul_flops={2 * n * buckets}")


def run():
    bench_attention_ref()
    bench_ssm_ref()
    bench_sort_ref()
    bench_partition_ref()


if __name__ == "__main__":
    run()
