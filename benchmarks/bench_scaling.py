"""Paper Figs 5-8 + Table 2 (execution time): join and sort weak/strong
scaling, runtime (RP) vs bare-metal (BM).

Real measurements on {1,2,4} host devices (CPU-sized rows), then the SAME
scheduler drives a calibrated virtual-clock simulation at the paper's rank
counts {148..518} — BM vs RP difference there is the measured constant
overhead.  Claims checked:
  C1 runtime-vs-BM parity (RP/BM ratio ~1 at equal parallelism)
  C4 weak scaling ~flat, strong scaling ~1/P
"""
from __future__ import annotations

import json

from benchmarks.common import FAST, emit, run_with_devices, trace_summary
from repro.core import SimOptions, TaskDescription, simulate

REAL_P = [1, 2, 4]
SIM_P = [148, 222, 296, 370, 444, 518]
ROWS_PER_RANK_WEAK = 30_000 if FAST else 200_000
ROWS_TOTAL_STRONG = 120_000 if FAST else 800_000

SNIPPET = r"""
import json, time, numpy as np, jax
from repro.core import build_communicator, LiveScheduler, TaskDescription, \
    PilotManager, PilotDescription
from repro.dataframe import ops_dist as D

P = %P%
op = "%OP%"
rows = %ROWS%
devices = jax.devices()[:P]
rng = np.random.default_rng(0)
cap = rows // P * 2 + 64

def make_table(comm):
    data = {"k": rng.integers(0, 1_000_000, rows).astype(np.int32),
            "v": rng.normal(size=rows).astype(np.float32)}
    return data

def payload(comm):
    data = make_table(comm)
    t = D.shard_table(comm, data, cap)
    if op == "sort":
        fn = D.make_dist_sort(comm.mesh, "k")
        out, ovf = fn(t)
    else:
        t2 = D.shard_table(comm, {"k": rng.integers(0, 1_000_000, rows).astype(np.int32),
                                  "w": rng.normal(size=rows).astype(np.float32)}, cap)
        fn = D.make_dist_join(comm.mesh, "k", out_factor=3.0)
        out, ovf = fn(t, t2)
    jax.block_until_ready(out.columns["k"])
    t0 = time.perf_counter()
    for _ in range(3):
        if op == "sort":
            out, _ = fn(t)
        else:
            out, _ = fn(t, t2)
    jax.block_until_ready(out.columns["k"])
    return (time.perf_counter() - t0) / 3

# BM: direct execution on a manually built communicator
comm = build_communicator(devices, axes=("df",))
bm = payload(comm)

# RP: same payload as a runtime task (private comm built by the scheduler)
pm = PilotManager(devices=devices)
pilot = pm.submit_pilot(PilotDescription(n_devices=P))
sched = LiveScheduler(pilot.resource_manager)
import time as _t
t0 = _t.perf_counter()
rep = sched.run([TaskDescription(name=op, ranks=P, fn=payload,
                                 tags={"pipeline": op})], timeout=600)
task = rep.tasks[0]
assert task.state.value == "DONE", task.error
rp = task.result
print("RESULT::" + json.dumps({"bm_s": bm, "rp_s": rp,
                               "comm_build_s": task.comm_build_time}))
"""


def _real_point(op: str, p: int, rows: int):
    out = run_with_devices(
        SNIPPET.replace("%P%", str(p)).replace("%OP%", op)
        .replace("%ROWS%", str(rows)), p, timeout=900)
    return json.loads(out.split("RESULT::")[1])


def _sim_points(op: str, scaling: str, base_time: float):
    """Calibrated simulation at paper scales.  duration_model: weak keeps
    rows/rank constant (slow log-P growth from the shuffle's splitter
    all-gather); strong divides fixed rows among ranks."""
    import math
    res = []
    for p in SIM_P:
        if scaling == "weak":
            dur = base_time * (1 + 0.02 * math.log2(p))
        else:
            dur = base_time * SIM_P[0] / p
        for mode in ("bm", "rp"):
            opts = SimOptions(noise=0.0,
                              overhead_model=(lambda r: 0.0) if mode == "bm"
                              else None or (lambda r: 2.8 + 0.0012 * r))
            rep = simulate([TaskDescription(name=op, ranks=p, fn=None,
                                            duration_model=lambda r, d=dur: d,
                                            tags={"pipeline": op})], p, opts)
            res.append({"op": op, "scaling": scaling, "mode": mode,
                        "parallelism": p, "time_s": rep.makespan,
                        "overhead_s": trace_summary(rep)["comm_build_total_s"]})
    return res


def run():
    results = []
    for op in ("join", "sort"):
        # real weak scaling: rows/rank fixed
        for p in REAL_P:
            r = _real_point(op, p, ROWS_PER_RANK_WEAK * p)
            results.append({"op": op, "scaling": "weak", "mode": "real",
                            "parallelism": p, **r})
            emit(f"scaling/{op}/weak/P={p}/bm", r["bm_s"] * 1e6,
                 f"rp_over_bm={r['rp_s'] / max(r['bm_s'], 1e-9):.3f}")
        # real strong scaling: total rows fixed
        for p in REAL_P:
            r = _real_point(op, p, ROWS_TOTAL_STRONG)
            results.append({"op": op, "scaling": "strong", "mode": "real",
                            "parallelism": p, **r})
            emit(f"scaling/{op}/strong/P={p}/bm", r["bm_s"] * 1e6,
                 f"rp_over_bm={r['rp_s'] / max(r['bm_s'], 1e-9):.3f}")
        # calibrated large-scale sim (paper Table 2 shape)
        weak_base = [x for x in results
                     if x["op"] == op and x["scaling"] == "weak"][0]["bm_s"]
        strong_base = [x for x in results
                       if x["op"] == op and x["scaling"] == "strong"][0]["bm_s"]
        # scale sim base to paper-sized rows (weak: 35M rows/rank; strong:
        # 3.5B rows total at the smallest paper parallelism)
        per_row = weak_base / ROWS_PER_RANK_WEAK     # s per row per rank
        sims = _sim_points(op, "weak", per_row * 35_000_000)
        per_row_s = strong_base / ROWS_TOTAL_STRONG
        sims += _sim_points(op, "strong",
                            per_row_s * 3_500_000_000 / SIM_P[0])
        results.extend(sims)
        for s in sims:
            if s["mode"] == "rp":
                emit(f"scaling/{op}/{s['scaling']}/P={s['parallelism']}/sim_rp",
                     s["time_s"] * 1e6, f"overhead_s={s['overhead_s']:.2f}")
    return results


if __name__ == "__main__":
    run()
