"""Roofline analysis from the dry-run artifacts (§Roofline of EXPERIMENTS.md).

Per (arch x shape) on the single-pod mesh:
  compute    = HLO_FLOPs / (chips * 197e12)            [s, per step]
  memory     = HLO_bytes / (chips * 819e9)             [s]
  collective = collective_bytes / (chips * 50e9)       [s]
with HLO terms taken from the unroll-extrapolated analysis pass (exact layer
counts; scan bodies are otherwise counted once by XLA cost analysis) and
collective_bytes = per-device ring traffic * chips.

All terms are already per-device quantities, so term = per_device_qty / rate.
MODEL_FLOPS: 6*N*D (train), 2*N*D (prefill), 2*N*B (decode) with N_active for
MoE; the ratio MODEL/HLO exposes remat + causal-mask + dispatch waste.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parent / "artifacts" / "dryrun"
PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link
DCN_BW = 6.25e9          # B/s / chip (multi-pod pod axis; 50 Gb/s assumption)


def model_flops_per_device(rec: dict, shapes: dict) -> float:
    kind = rec["kind"]
    n_act = rec["model"]["active_params"]
    gb, seq = shapes["global_batch"], shapes["seq_len"]
    if kind == "train":
        total = 6.0 * n_act * gb * seq
    elif kind == "prefill":
        total = 2.0 * n_act * gb * seq
    else:  # decode: one token per sequence
        total = 2.0 * n_act * gb
    return total / rec["n_devices"]


def analyze(tag: str = "baseline", mesh: str = "single"):
    from repro.configs import SHAPES

    rows = []
    for f in sorted(ART.glob(f"*__{mesh}__{tag}.json")):
        rec = json.loads(f.read_text())
        if rec.get("skipped"):
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "skipped": True, "reason": rec["reason"]})
            continue
        ana = rec.get("analysis")
        if not ana:
            continue
        sh = SHAPES[rec["shape"]]
        t_c = ana["flops"] / PEAK_FLOPS
        t_m = ana["bytes"] / HBM_BW
        t_x = ana["ici_traffic_bytes_per_device"] / ICI_BW
        t_d = ana.get("dcn_traffic_bytes_per_device", 0.0) / DCN_BW
        dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x + t_d),
                  key=lambda kv: kv[1])[0]
        mf = model_flops_per_device(rec, {"global_batch": sh.global_batch,
                                          "seq_len": sh.seq_len})
        bound = max(t_c, t_m, t_x + t_d)
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "skipped": False,
            "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x + t_d,
            "dominant": dom,
            "model_flops_per_dev": mf,
            "hlo_flops_per_dev": ana["flops"],
            "model_over_hlo": mf / max(ana["flops"], 1.0),
            "roofline_fraction": t_c / max(bound, 1e-12),
            "memory_temp_gb": rec["memory"]["temp_bytes"] / 1e9,
            "memory_args_gb": rec["memory"]["argument_bytes"] / 1e9,
        })
    return rows


def render_markdown(rows) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO | roofline frac | temp GB/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | SKIPPED "
                       f"| — | — | — |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} "
            f"| {r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} "
            f"| {r['dominant']} | {r['model_over_hlo']:.3f} "
            f"| {r['roofline_fraction']:.3f} | {r['memory_temp_gb']:.1f} |")
    return "\n".join(out)


def run(tag: str = "baseline"):
    rows = analyze(tag)
    for r in rows:
        if r.get("skipped"):
            continue
        from benchmarks.common import emit
        emit(f"roofline/{r['arch']}/{r['shape']}",
             max(r['t_compute_s'], r['t_memory_s'], r['t_collective_s']) * 1e6,
             f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f}")
    (ART.parent / f"roofline_{tag}.json").write_text(json.dumps(rows, indent=1))
    (ART.parent / f"roofline_{tag}.md").write_text(render_markdown(rows))
    return rows


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else "baseline")
