"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (see benchmarks/common.emit).
BENCH_FAST=1 (default) runs CI-sized inputs; BENCH_FAST=0 runs the full
sizes.  The dry-run/roofline section only reports cells whose artifacts
exist (run ``python -m repro.launch.dryrun --all`` first for the full table).
"""
from __future__ import annotations

import sys
import traceback


def _section(name, fn):
    print(f"# === {name} ===", flush=True)
    try:
        fn()
    except Exception as e:  # noqa: BLE001 — keep the harness running
        print(f"# SECTION FAILED {name}: {type(e).__name__}: {e}", flush=True)
        traceback.print_exc()
        return False
    return True


def main() -> None:
    from benchmarks import (bench_hetero, bench_kernels, bench_overhead,
                            bench_scaling, roofline)

    ok = True
    # paper Table 2 (overhead column): communicator construction vs ranks
    ok &= _section("overhead (paper Table 2)", bench_overhead.run)
    # paper Figs 5-8 + Table 2: join/sort weak+strong scaling, BM vs RP
    ok &= _section("scaling join/sort (paper Figs 5-8)", bench_scaling.run)
    # paper Figs 9-11: heterogeneous vs batch (the 4-15% claim)
    ok &= _section("heterogeneous vs batch (paper Figs 9-11)", bench_hetero.run)
    # kernel hot-spots (paper §4.4 discussion)
    ok &= _section("kernel hot-spots", bench_kernels.run)
    # roofline table from dry-run artifacts (this repro's §Roofline)
    ok &= _section("roofline (from dry-run artifacts)", roofline.run)
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
