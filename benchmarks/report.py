"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts (idempotent: replaces text between AUTOGEN markers), plus
``trace_gantt``: a per-device ASCII timeline + utilization rendered straight
from a SimReport's TraceEvent stream (works for virtual-clock, thread, and
process executors alike — they all emit the same schema)."""
from __future__ import annotations

import heapq
import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "benchmarks" / "artifacts" / "dryrun"

_GANTT_CHARS = "ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789"


def trace_gantt(report, width: int = 64) -> str:
    """Markdown Gantt of device occupancy from ``report.trace``.

    Device *lanes* are reconstructed from the event stream: a dispatch (or
    speculate) occupies ``ranks`` lanes until that task's done/fail/cancel/
    retry event frees them — the same assignment the ResourceManager made,
    modulo lane naming.  Returns a legend, one row per lane with its busy
    fraction, and the overall utilization percentage.

    When the report carries worker flight-recorder spans (a process-executor
    run, or a trace loaded from its JSONL), the heuristic lanes are replaced
    by TRUE per-worker lanes with compute-vs-wait shading — see
    :func:`_span_gantt`.  Span-less reports keep the heuristic path."""
    if getattr(report, "spans", None):
        return _span_gantt(report, width)
    events = sorted(report.trace, key=lambda e: e.t)
    if not events:
        return "(empty trace)"
    free: list = []                     # min-heap of free lane ids
    next_lane = 0
    open_by_uid: dict = {}        # uid -> (lanes, t_start, task name, spec)
    intervals: list = []                # (lane, t0, t1, task name)

    def close(uid, t):
        lanes, t_start, name, _ = open_by_uid.pop(uid)
        for ln in lanes:
            intervals.append((ln, t_start, t, name))
            heapq.heappush(free, ln)

    for e in events:
        if e.kind in ("dispatch", "speculate"):
            lanes = []
            for _ in range(max(e.ranks, 1)):
                if free:
                    lanes.append(heapq.heappop(free))
                else:
                    lanes.append(next_lane)
                    next_lane += 1
            open_by_uid[e.uid] = (lanes, e.t, e.task, e.kind == "speculate")
        elif e.kind in ("done", "fail", "cancel", "retry"):
            if e.uid in open_by_uid:
                close(e.uid, e.t)
            if e.kind == "done":
                # a spec-exec duplicate's completion is credited to the
                # PRIMARY's uid, so the duplicate's speculate-opened lanes
                # would otherwise leak.  Only sweep speculate-opened twins:
                # concurrent ordinary tasks may legitimately share a name.
                for uid in [u for u, v in open_by_uid.items()
                            if v[2] == e.task and v[3]]:
                    close(uid, e.t)
    t0 = events[0].t
    t1 = max(e.t for e in events)
    for uid in list(open_by_uid):       # still running at trace end
        close(uid, t1)
    span = t1 - t0
    if span <= 0 or not intervals:
        return "(no occupancy to render)"

    names = []
    for _, _, _, name in intervals:
        if name not in names:
            names.append(name)
    char_of = {n: _GANTT_CHARS[i % len(_GANTT_CHARS)]
               for i, n in enumerate(names)}
    n_lanes = max(ln for ln, *_ in intervals) + 1
    rows = [["·"] * width for _ in range(n_lanes)]
    busy = [0.0] * n_lanes
    for ln, a, b, name in intervals:
        busy[ln] += b - a
        lo = int((a - t0) / span * width)
        hi = max(int((b - t0) / span * width), lo + 1)
        for c in range(lo, min(hi, width)):
            rows[ln][c] = char_of[name]
    legend = "  ".join(f"{char_of[n]}={n}" for n in names)
    out = [f"trace gantt  (span {span:.3f}s, {n_lanes} devices)",
           legend, "```"]
    for ln in range(n_lanes):
        out.append(f"dev{ln:<3d} |{''.join(rows[ln])}| "
                   f"{busy[ln] / span * 100:5.1f}%")
    util = sum(busy) / (n_lanes * span) * 100
    out += ["```", f"overall utilization: {util:.1f}%"]
    return "\n".join(out)


def _span_gantt(report, width: int = 64) -> str:
    """Per-worker Gantt rendered from recorded flight-recorder spans: one
    lane per (worker, concurrent part slot), compute shaded with the task's
    legend letter, wait spans (``p2p_recv`` — blocked on a peer frame or a
    hub collective) shaded ``~``, other local work (deserialize, comm_build,
    spill/merge) shaded ``=``.  Unlike the heuristic event-stream path this
    is measured occupancy, not inferred: idle gaps between spans stay
    blank."""
    from repro.obs.spans import WAIT_KINDS

    spans = sorted(report.spans, key=lambda s: (s.get("worker", ""),
                                                s["t0"], s["t1"]))
    t0 = min(s["t0"] for s in spans)
    t1 = max(s["t1"] for s in spans)
    span = t1 - t0
    if span <= 0:
        return "(no occupancy to render)"

    names = []
    for s in spans:
        n = s.get("task", "") or f"uid{s.get('uid', -1)}"
        if n not in names:
            names.append(n)
    char_of = {n: _GANTT_CHARS[i % len(_GANTT_CHARS)]
               for i, n in enumerate(names)}

    # lane assignment: per worker, concurrent (uid, part) occupants get
    # separate lanes (greedy earliest-start, lowest free lane)
    by_worker: dict = {}
    for s in spans:
        by_worker.setdefault(s.get("worker", "worker"), []).append(s)
    out = [f"trace gantt  (span {span:.3f}s, {len(by_worker)} workers, "
           f"span-traced)",
           "  ".join(f"{char_of[n]}={n}" for n in names),
           "legend: letter=compute  ~=wait (p2p/hub)  ==other work", "```"]
    total_busy = 0.0
    n_lanes = 0
    for wid in sorted(by_worker):
        part_iv: dict = {}
        for s in by_worker[wid]:
            key = (s.get("uid", -1), s.get("part", 0))
            lo, hi = part_iv.get(key, (s["t0"], s["t1"]))
            part_iv[key] = (min(lo, s["t0"]), max(hi, s["t1"]))
        lane_free: list = []
        lane_of: dict = {}
        for key, (lo, hi) in sorted(part_iv.items(), key=lambda kv: kv[1]):
            for i, free_at in enumerate(lane_free):
                if lo >= free_at:
                    lane_free[i] = hi
                    lane_of[key] = i
                    break
            else:
                lane_of[key] = len(lane_free)
                lane_free.append(hi)
        rows = [["·"] * width for _ in lane_free]
        busy = [0.0] * len(lane_free)
        # paint coarse->fine so wait/other shading overlays the enclosing
        # compute span rather than being hidden by it
        order = {"compute": 0}
        for s in sorted(by_worker[wid],
                        key=lambda s: order.get(s["kind"], 1)):
            key = (s.get("uid", -1), s.get("part", 0))
            ln = lane_of[key]
            if s["kind"] == "compute":
                busy[ln] += s["t1"] - s["t0"]
                ch = char_of[s.get("task", "") or f"uid{s.get('uid', -1)}"]
            elif s["kind"] in WAIT_KINDS:
                ch = "~"
            else:
                ch = "="
            lo = int((s["t0"] - t0) / span * width)
            hi = max(int((s["t1"] - t0) / span * width), lo + 1)
            for c in range(lo, min(hi, width)):
                rows[ln][c] = ch
        for i, row in enumerate(rows):
            out.append(f"{wid}.{i:<2d} |{''.join(row)}| "
                       f"{busy[i] / span * 100:5.1f}%")
        total_busy += sum(busy)
        n_lanes += len(rows)
    util = total_busy / (n_lanes * span) * 100 if n_lanes else 0.0
    out += ["```", f"overall compute utilization: {util:.1f}%"]
    return "\n".join(out)


def dryrun_table(mesh: str, tag: str = "baseline") -> str:
    rows = ["| arch | shape | kind | compile s | args GB/dev | temp GB/dev "
            "| #coll ops | ICI GB/dev | DCN GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(ART.glob(f"*__{mesh}__{tag}.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | SKIPPED "
                        f"({r['reason'][:40]}…) | | | | | |")
            continue
        c = r["collectives"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['timing']['compile_s']:.0f} "
            f"| {r['memory']['argument_bytes'] / 1e9:.2f} "
            f"| {r['memory']['temp_bytes'] / 1e9:.1f} "
            f"| {c['n_collective_ops']} "
            f"| {c['ici_traffic_bytes_per_device'] / 1e9:.2f} "
            f"| {c['dcn_traffic_bytes_per_device'] / 1e9:.2f} |")
    return "\n".join(rows)


def roofline_table(tag: str = "baseline") -> str:
    sys.path.insert(0, str(ROOT))
    from benchmarks.roofline import analyze, render_markdown
    return render_markdown(analyze(tag))


def splice(md_path: Path, marker: str, content: str):
    text = md_path.read_text() if md_path.exists() else ""
    begin = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- AUTOGEN:END:{marker} -->"
    block = f"{begin}\n{content}\n{end}"
    if begin in text:
        text = re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    else:
        text += "\n" + block + "\n"
    md_path.write_text(text)


def main():
    md = ROOT / "EXPERIMENTS.md"
    splice(md, "dryrun-single", dryrun_table("single"))
    splice(md, "dryrun-multi", dryrun_table("multi"))
    splice(md, "roofline", roofline_table())
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
