"""Render the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
dry-run artifacts (idempotent: replaces text between AUTOGEN markers)."""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
ART = ROOT / "benchmarks" / "artifacts" / "dryrun"


def dryrun_table(mesh: str, tag: str = "baseline") -> str:
    rows = ["| arch | shape | kind | compile s | args GB/dev | temp GB/dev "
            "| #coll ops | ICI GB/dev | DCN GB/dev |",
            "|---|---|---|---|---|---|---|---|---|"]
    for f in sorted(ART.glob(f"*__{mesh}__{tag}.json")):
        r = json.loads(f.read_text())
        if r.get("skipped"):
            rows.append(f"| {r['arch']} | {r['shape']} | — | SKIPPED "
                        f"({r['reason'][:40]}…) | | | | | |")
            continue
        c = r["collectives"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['timing']['compile_s']:.0f} "
            f"| {r['memory']['argument_bytes'] / 1e9:.2f} "
            f"| {r['memory']['temp_bytes'] / 1e9:.1f} "
            f"| {c['n_collective_ops']} "
            f"| {c['ici_traffic_bytes_per_device'] / 1e9:.2f} "
            f"| {c['dcn_traffic_bytes_per_device'] / 1e9:.2f} |")
    return "\n".join(rows)


def roofline_table(tag: str = "baseline") -> str:
    sys.path.insert(0, str(ROOT))
    from benchmarks.roofline import analyze, render_markdown
    return render_markdown(analyze(tag))


def splice(md_path: Path, marker: str, content: str):
    text = md_path.read_text() if md_path.exists() else ""
    begin = f"<!-- AUTOGEN:{marker} -->"
    end = f"<!-- AUTOGEN:END:{marker} -->"
    block = f"{begin}\n{content}\n{end}"
    if begin in text:
        text = re.sub(re.escape(begin) + r".*?" + re.escape(end), block,
                      text, flags=re.S)
    else:
        text += "\n" + block + "\n"
    md_path.write_text(text)


def main():
    md = ROOT / "EXPERIMENTS.md"
    splice(md, "dryrun-single", dryrun_table("single"))
    splice(md, "dryrun-multi", dryrun_table("multi"))
    splice(md, "roofline", roofline_table())
    print("EXPERIMENTS.md tables updated")


if __name__ == "__main__":
    main()
