"""Out-of-core shuffle scaling (BENCH_SHUFFLE=1): the paper's claim surface
— distributed sort/join wall time vs row count on a multi-worker pilot
(Radical-Cylon reports 35M/3.5B-row joins; this is the same shape at CI
scale, growable via BENCH_FAST=0).

Two sections, both landing in ``benchmarks/artifacts/shuffle_summary.json``:

* **scaling** — rows-vs-wall curve for the out-of-core sample sort on 2
  workers under a memory budget ~1/3 of the per-part dataset, so the spill
  path is exercised at every size; each row records the full evidence
  (``p2p_bytes``, ``hub_relay_bytes``, ``hub_calls``, ``spills``) read
  back from the ONE TraceEvent stream via ``trace_summary``.
* **framing** — raw-buffer peer frames (``PEER_DATA_RAW``) vs pickled
  ``PEER_DATA`` for the identical multi-MiB bucket exchange: the transport
  A/B behind the REPRO_RAW_FRAMES knob, timed inside the task so only the
  exchange is measured.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import ART, FAST, ROOT, emit, trace_summary
from repro.core import ProcessExecutor, SchedulerSession, TaskDescription
from repro.dataframe.shuffle import sort_task

SIZES = [25_000, 50_000, 100_000, 250_000] if FAST else \
    [50_000, 100_000, 250_000, 500_000, 1_000_000]

_ROW_BYTES = 12     # int32 key + one int64 value column


def _warm(ex):
    """First dispatch per worker pays payload-import cost; keep it out of
    the measured runs."""
    sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005)
    sess.run([TaskDescription(name="warm", ranks=2, fn=sort_task,
                              args=({"rows_per_part": 1000,
                                     "budget": 1 << 30},),
                              tags={"pipeline": "bench"})], timeout=120)


def scaling_curve(n_workers: int = 2):
    """Rows-vs-wall for the out-of-core sort; budget = per-part bytes / 3,
    so every size spills (budget < dataset) — the acceptance shape."""
    rows = []
    with ProcessExecutor(n_workers=n_workers, devices_per_worker=1,
                         build_comm=False, tick=0.005,
                         extra_pythonpath=[str(ROOT)]) as ex:
        _warm(ex)
        for rpp in SIZES:
            budget = max(64 << 10, (rpp * _ROW_BYTES) // 3)
            spec = {"rows_per_part": rpp, "seed": 42, "budget": budget}
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005)
            rep = sess.run([TaskDescription(
                name=f"sort{rpp}", ranks=n_workers, fn=sort_task,
                args=(spec,), tags={"pipeline": "bench"})], timeout=600)
            task = rep.tasks[0]
            assert task.error is None, task.error
            assert task.result["sorted"] and \
                task.result["n"] == rpp * n_workers
            name = f"sort{rpp}"
            disp = next(e.t for e in rep.trace
                        if e.kind == "dispatch" and e.task == name)
            done = next(e.t for e in rep.trace
                        if e.kind == "done" and e.task == name)
            wall = done - disp
            ts = trace_summary(rep)
            row = {
                "rows": rpp * n_workers, "rows_per_part": rpp,
                "n_workers": n_workers, "wall_s": wall,
                "dataset_bytes_per_part": rpp * _ROW_BYTES,
                "budget_bytes": budget,
                "spills": task.spills,
                "p2p_bytes": task.p2p_bytes,
                "hub_relay_bytes": ex.hub_relay_bytes,
                "hub_calls": task.hub_calls,
                "trace_summary": ts,
            }
            rows.append(row)
            emit(f"shuffle/sort/rows={rpp * n_workers}", wall * 1e6,
                 f"spills={task.spills};p2p_bytes={task.p2p_bytes};"
                 f"hub_relay_bytes={ex.hub_relay_bytes};budget={budget}")
            assert task.spills > 0, "budget < dataset must exercise spill"
            if ex.p2p and ex.raw_frames:
                assert task.p2p_bytes > 10 * ex.hub_relay_bytes, \
                    "bucket bytes must move p2p, not through the hub"
    return rows


def _xchg_probe(comm, n_rounds=4, rows=60_000, width=4):
    """Transport-only probe: ``n_rounds`` personalized all-to-alls of the
    same per-destination buckets, timed inside the task so generation and
    merge never pollute the comparison.  At the defaults each bucket is
    ~1 MiB (rows/2 * (4 + width*8) bytes on 2 parts)."""
    import time as _t

    import numpy as np
    n_parts = comm.n_parts
    rng = np.random.default_rng(comm.part)
    cols = {"key": rng.integers(0, 1 << 30, rows, dtype=np.int32)}
    for j in range(width):
        cols[f"v{j}"] = rng.integers(0, 1 << 62, rows, dtype=np.int64)
    chunks = [{k: np.ascontiguousarray(v[d::n_parts])
               for k, v in cols.items()} for d in range(n_parts)]
    bucket_bytes = sum(v.nbytes for v in chunks[0].values())
    t0 = _t.perf_counter()
    for _ in range(n_rounds):
        got = comm.all_to_all_arrays(chunks)
        assert len(got) == n_parts
    return {"xchg_s": _t.perf_counter() - t0,
            "bucket_bytes": bucket_bytes,
            "p2p_bytes": comm.p2p_bytes,
            "fallbacks": comm.p2p_fallbacks}


def framing_compare(n_rounds: int = 4, rows: int = 60_000, width: int = 4):
    """Raw-buffer frames vs pickled frames for the identical >= 1 MiB
    bucket exchange (the REPRO_RAW_FRAMES A/B)."""
    out = {}
    for raw in (False, True):
        with ProcessExecutor(n_workers=2, devices_per_worker=1,
                             build_comm=False, tick=0.005,
                             raw_frames=raw,
                             extra_pythonpath=[str(ROOT)]) as ex:
            sess = SchedulerSession(ex, ex.resource_manager(), tick=0.005)
            sess.run([TaskDescription(name="warm", ranks=2, fn=_xchg_probe,
                                      kwargs={"n_rounds": 1, "rows": 2000},
                                      tags={"pipeline": "bench"})],
                     timeout=120)
            rep = sess.run([TaskDescription(
                name="probe", ranks=2, fn=_xchg_probe,
                kwargs={"n_rounds": n_rounds, "rows": rows, "width": width},
                tags={"pipeline": "bench"})], timeout=300)
            probe = [t for t in rep.tasks if t.desc.name == "probe"][0]
            assert probe.error is None, probe.error
            mode = "raw" if raw else "pickled"
            out[mode] = {**probe.result, "p2p_bytes": probe.p2p_bytes,
                         "hub_relay_bytes": ex.hub_relay_bytes}
            emit(f"shuffle/framing/{mode}", out[mode]["xchg_s"] * 1e6,
                 f"bucket_bytes={out[mode]['bucket_bytes']};"
                 f"rounds={n_rounds};p2p_bytes={probe.p2p_bytes}")
    speedup = out["pickled"]["xchg_s"] / max(out["raw"]["xchg_s"], 1e-9)
    out["speedup_pickled_over_raw"] = speedup
    emit("shuffle/framing/speedup_pickled_over_raw", speedup * 1e6,
         ">1 means raw-buffer framing wins")
    return out


def run():
    if os.environ.get("BENCH_SHUFFLE", "0") != "1" and \
            "--shuffle" not in sys.argv:
        print("bench_shuffle: set BENCH_SHUFFLE=1 (spawns worker "
              "interpreters); skipping")
        return {}
    res = {"scaling": scaling_curve(), "framing": framing_compare()}
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "shuffle_summary.json").write_text(
        json.dumps(res, indent=2, default=str))
    return res


if __name__ == "__main__":
    run()
