"""Shared benchmark helpers: timing, CSV emission, subprocess launch."""
from __future__ import annotations

import os
import statistics
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
ART = ROOT / "benchmarks" / "artifacts"
FAST = os.environ.get("BENCH_FAST", "1") == "1"   # default: CI-sized


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_call(fn, *args, iters: int = 5, warmup: int = 2):
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def run_with_devices(snippet: str, n_devices: int, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout
