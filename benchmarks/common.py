"""Shared benchmark helpers: timing, CSV emission, subprocess launch."""
from __future__ import annotations

import os
import statistics
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
SRC = str(ROOT / "src")
ART = ROOT / "benchmarks" / "artifacts"
FAST = os.environ.get("BENCH_FAST", "1") == "1"   # default: CI-sized


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def time_call(fn, *args, iters: int = 5, warmup: int = 2):
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return statistics.median(ts)


def run_with_devices(snippet: str, n_devices: int, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", snippet], env=env,
                       capture_output=True, text=True, timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{r.stdout}\n{r.stderr}")
    return r.stdout


def trace_summary(report) -> dict:
    """Uniform consumer of the scheduler event trace (SimReport.trace) —
    shared by bench_hetero / bench_scaling / bench_overhead so live and
    simulated runs report identical schedule-derived metrics."""
    from collections import Counter

    kinds = Counter(e.kind for e in report.trace)
    submits = {e.uid: e.t for e in report.trace if e.kind == "submit"}
    waits = [e.t - submits[e.uid] for e in report.trace
             if e.kind == "dispatch" and e.uid in submits]
    comm = [e.value for e in report.trace if e.kind == "comm_build"]
    out = {
        "n_submit": kinds.get("submit", 0),
        "n_dispatch": kinds.get("dispatch", 0),
        "n_done": kinds.get("done", 0),
        "n_retry": kinds.get("retry", 0),
        "n_speculate": kinds.get("speculate", 0),
        # elastic-pool evidence: grow/retire events the core absorbed
        # (add_worker/retire_worker, inject_grow/inject_retire, grow_at/
        # retire_at) — zeros on a static-pool run
        "n_grow": kinds.get("grow", 0),
        "n_retire": kinds.get("retire", 0),
        "mean_wait_s": sum(waits) / len(waits) if waits else 0.0,
        "comm_build_total_s": sum(comm),
        "comm_build_mean_s": sum(comm) / len(comm) if comm else 0.0,
        # data-plane evidence, uniform across backends: the process executor
        # reports real worker-to-worker bytes / hub round-trips; thread and
        # virtual runs report plain zeros (never a KeyError downstream)
        "p2p_bytes": sum(getattr(e, "p2p", 0.0)
                         for e in report.trace if e.kind in ("done", "fail")),
        "hub_calls": sum(getattr(t, "hub_calls", 0) for t in report.tasks),
        "spills": sum(getattr(t, "spills", 0) for t in report.tasks),
        "p2p_fallbacks": sum(getattr(t, "p2p_fallbacks", 0)
                             for t in report.tasks),
        "hub_relay_bytes": sum(getattr(t, "hub_relay_bytes", 0)
                               for t in report.tasks),
        # transport-tier evidence: zero-copy framed bytes, same-host
        # shared-memory bytes, and ring-allgather forwards (PR 8)
        "raw_coll_bytes": sum(getattr(t, "raw_coll_bytes", 0)
                              for t in report.tasks),
        "shm_bytes": sum(getattr(t, "shm_bytes", 0) for t in report.tasks),
        "ring_steps": sum(getattr(t, "ring_steps", 0)
                          for t in report.tasks),
        # crash-safe resume + result-cache evidence (PR 10): attempts that
        # restored a checkpoint instead of re-running from scratch, the
        # steps they skipped, and tasks completed straight from the
        # result cache — zeros on runs without REPRO_CKPT_DIR/RESULT_CACHE
        "n_resume": kinds.get("resume", 0),
        "resumed_steps": sum(getattr(t, "resumed_from_step", 0)
                             for t in report.tasks),
        "cache_hits": kinds.get("cache_hit", 0),
    }
    # span-derived timing breakdown, present only when worker flight-recorder
    # spans exist (process executor with instrumented workers, or a loaded
    # trace of such a run); sim/thread reports simply omit the keys
    spans = getattr(report, "spans", None) or ()
    if spans:
        from repro.obs.spans import WAIT_KINDS
        out["compute_s"] = sum(s["t1"] - s["t0"] for s in spans
                               if s["kind"] == "compute")
        out["comm_wait_s"] = sum(s["t1"] - s["t0"] for s in spans
                                 if s["kind"] in WAIT_KINDS)
    return out
