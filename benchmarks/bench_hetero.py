"""Paper Figs 9-11: heterogeneous (shared-pool) vs batch (static-partition)
execution of mixed join+sort pipelines — the paper's headline 4-15% win.

Two layers of evidence:
  * REAL: LiveScheduler on 4 host devices running actual dataframe tasks
    under both policies (subprocess).
  * CALIBRATED SIM: the same scheduler at the paper's ORNL scales
    (84..2688 ranks) with duration models calibrated from the real runs and
    task mixes shaped like the paper's (join WS/SS + sort WS/SS).
"""
from __future__ import annotations

import json

from benchmarks.common import FAST, emit, run_with_devices, trace_summary
from repro.core import BATCH, HETEROGENEOUS, SimOptions, TaskDescription, simulate

SIM_RANKS = [84, 168, 336, 672, 1344, 2688]

REAL_SNIPPET = r"""
import json, time, numpy as np, jax
from repro.core import (BATCH, HETEROGENEOUS, LiveScheduler, PilotDescription,
                        PilotManager, TaskDescription)
from repro.dataframe import ops_dist as D

rng = np.random.default_rng(0)
ROWS = %ROWS%

def sort_payload(comm):
    data = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32)}
    t = D.shard_table(comm, data, ROWS // comm.size * 2 + 64)
    out, _ = D.make_dist_sort(comm.mesh, "k")(t)
    jax.block_until_ready(out.columns["k"])
    time.sleep(0.6)   # 1-core container: residual work simulated via sleep so
                      # cross-task overlap is real (see DESIGN.md §10)
    return comm.size

def join_payload(comm):
    a = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
         "v": rng.normal(size=ROWS).astype(np.float32)}
    b = {"k": rng.integers(0, 1_000_000, ROWS).astype(np.int32),
         "w": rng.normal(size=ROWS).astype(np.float32)}
    cap = ROWS // comm.size * 2 + 64
    out, _ = D.make_dist_join(comm.mesh, "k", out_factor=3.0)(
        D.shard_table(comm, a, cap), D.shard_table(comm, b, cap))
    jax.block_until_ready(out.columns["k"])
    time.sleep(1.8)   # joins are the long pole (see sort_payload note)
    return comm.size

def mix():
    # imbalanced mix: joins are heavier; sorts release resources early
    descs = []
    for i in range(2):
        descs.append(TaskDescription(name=f"join{i}", ranks=2, fn=join_payload,
                                     tags={"pipeline": "join"}))
    for i in range(4):
        descs.append(TaskDescription(name=f"sort{i}", ranks=2, fn=sort_payload,
                                     tags={"pipeline": "sort"}))
    return descs

res = {}
for policy in (HETEROGENEOUS, BATCH):
    pm = PilotManager()
    pilot = pm.submit_pilot(PilotDescription(n_devices=4))
    sched = LiveScheduler(pilot.resource_manager, policy)
    t0 = time.perf_counter()
    rep = sched.run(mix(), timeout=900)
    assert all(t.state.value == "DONE" for t in rep.tasks), \
        [(t.desc.name, t.error) for t in rep.tasks]
    # event trace: same schema as the virtual-clock sim
    res[policy] = rep.makespan
    res[policy + "/n_dispatch"] = sum(e.kind == "dispatch" for e in rep.trace)
    res[policy + "/comm_build_s"] = sum(
        e.value for e in rep.trace if e.kind == "comm_build")
print("RESULT::" + json.dumps(res))
"""


def paper_mix(ranks_per_task: int, n_join: int, n_sort: int,
              join_s: float, sort_s: float):
    descs = []
    for i in range(n_join):
        descs.append(TaskDescription(
            name=f"join{i}", ranks=ranks_per_task, fn=None,
            duration_model=lambda r, d=join_s: d, tags={"pipeline": "join"}))
    for i in range(n_sort):
        descs.append(TaskDescription(
            name=f"sort{i}", ranks=ranks_per_task, fn=None,
            duration_model=lambda r, d=sort_s: d, tags={"pipeline": "sort"}))
    return descs


def run():
    rows = 20_000 if FAST else 120_000
    out = run_with_devices(REAL_SNIPPET.replace("%ROWS%", str(rows)), 4,
                           timeout=900)
    real = json.loads(out.split("RESULT::")[1])
    impr = (real[BATCH] - real[HETEROGENEOUS]) / real[BATCH] * 100
    emit("hetero/real/heterogeneous", real[HETEROGENEOUS] * 1e6,
         f"improvement_pct={impr:.1f};"
         f"n_dispatch={real[HETEROGENEOUS + '/n_dispatch']};"
         f"comm_build_s={real[HETEROGENEOUS + '/comm_build_s']:.3f}")
    emit("hetero/real/batch", real[BATCH] * 1e6,
         f"n_dispatch={real[BATCH + '/n_dispatch']}")

    results = [{"mode": "real", "ranks": 4, "het": real[HETEROGENEOUS],
                "bat": real[BATCH], "impr_pct": impr}]
    # paper-scale sim, three configurations like Fig 11 (mix imbalance varies
    # the win; paper band 4-15%).  Durations are Table 2-like join/sort WS
    # times.  NOTE (documented in EXPERIMENTS.md): on perfectly-packable
    # symmetric mixes batch partitioning can tie the shared pool — the
    # paper's win comes from batch leaving released resources idle.
    CONFIGS = {"cfgA": (4, 4, 250.0, 190.0),   # ~12%
               "cfgB": (3, 3, 230.0, 205.0),   # ~5%
               "cfgC": (4, 4, 230.0, 215.0)}   # ~3%
    for cname, margs in CONFIGS.items():
        for ranks in SIM_RANKS:
            per_task = ranks // 4
            het = simulate(paper_mix(per_task, *margs), ranks,
                           SimOptions(policy=HETEROGENEOUS, noise=0.0, seed=1))
            bat = simulate(paper_mix(per_task, *margs), ranks,
                           SimOptions(policy=BATCH, noise=0.0, seed=1))
            impr = (bat.makespan - het.makespan) / bat.makespan * 100
            ts = trace_summary(het)
            results.append({"mode": f"sim/{cname}", "ranks": ranks,
                            "het": het.makespan, "bat": bat.makespan,
                            "impr_pct": impr, "trace": ts})
            emit(f"hetero/sim/{cname}/ranks={ranks}", het.makespan * 1e6,
                 f"batch_s={bat.makespan:.1f};improvement_pct={impr:.1f};"
                 f"mean_wait_s={ts['mean_wait_s']:.1f}")
    return results


if __name__ == "__main__":
    run()
